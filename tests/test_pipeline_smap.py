"""The layer-split plan as a real SPMD pipeline (shard_map + ppermute),
validated against the monolithic forward on a 4-device mesh.  Runs in a
subprocess so the forced host-device count doesn't leak into this
process."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.models import forward, init_params
from repro.serving.pipeline_smap import pipeline_shard_map

cfg = get_config("tinyllama-1.1b").reduced(max_layers=4)
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.RandomState(0)
tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 16)), jnp.int32)
batch = {"tokens": tokens}
want, _ = forward(params, batch, cfg)

mesh = jax.make_mesh((4,), ("stage",))
for M in (4, 8):
    got = pipeline_shard_map(params, batch, cfg, mesh, num_microbatches=M)
    err = float(jnp.abs(got - want).max())
    assert err < 2e-4, (M, err)
    print(f"M={M} err={err:.2e} OK")
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_pipeline_shard_map_matches_forward():
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PIPELINE_OK" in r.stdout
