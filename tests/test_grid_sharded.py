"""shard_map grid dispatch ≡ thread-chunk dispatch under 8 forced host
devices.

The acceptance contract for the device-scale dispatcher: an *uneven*
grid (G not a multiple of the mesh size, so dead padded cells are in
play) run through ``run_grid_arrays(devices=8)`` must match the
thread-chunk path within ``allclose(rtol=1e-4)`` on every summary
metric, for the static engine AND the splitplace learned engine in both
deploy and train modes.  Runs in a subprocess so the forced host-device
count doesn't leak into this process (tier-1 runs single-device).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro.core import daso, mab
from repro.env import jaxsim

assert len(jax.devices()) == 8, jax.devices()

def check(name, thr, shd):
    assert len(thr) == len(shd) != 0, (name, len(thr), len(shd))
    for i, (a, b) in enumerate(zip(thr, shd)):
        for k in a:
            if isinstance(a[k], (int, float)):
                assert np.isclose(a[k], b[k], rtol=1e-4, atol=1e-9), \
                    (name, i, k, a[k], b[k])
    print(f"{name}: {len(thr)} rows match OK")

# uneven: 5 traces on an 8-device mesh -> 3 dead padded cells
dec = jaxsim.make_static_decider("mc")
traces = [jaxsim.compile_trace(dec, lam=lam, seed=s, n_intervals=4,
                               substeps=4)
          for lam in (3.0, 6.0) for s in (0, 1, 2)][:5]
check("static",
      jaxsim.run_grid_arrays(traces, threads=2),
      jaxsim.run_grid_arrays(traces, devices=8))

st = mab.init_state(3)._replace(
    R=jnp.array([700.0, 1800.0, 3500.0], jnp.float32),
    Q=jnp.array([[0.8, 0.6], [0.3, 0.7]], jnp.float32),
    N=jnp.array([[20.0, 10.0], [5.0, 25.0]], jnp.float32),
    eps=jnp.asarray(0.4, jnp.float32), rho=jnp.asarray(0.06, jnp.float32),
    t=jnp.asarray(40, jnp.int32))
cfg = daso.DASOConfig(num_workers=50, max_containers=16, state_features=4,
                      hidden=32, depth=2, place_iters=12)
theta = daso.init_surrogate(jax.random.PRNGKey(0), cfg)
dtr = [jaxsim.compile_trace_dual(lam=lam, seed=s, n_intervals=4,
                                 substeps=4)
       for lam in (3.0, 6.0) for s in (0, 1, 2)][:5]
check("splitplace deploy",
      jaxsim.run_grid_arrays_learned(dtr, st, daso_theta=theta,
                                     daso_cfg=cfg, threads=2),
      jaxsim.run_grid_arrays_learned(dtr, st, daso_theta=theta,
                                     daso_cfg=cfg, devices=8))
check("splitplace train",
      jaxsim.run_grid_arrays_trained(dtr, st, daso_theta=theta,
                                     daso_cfg=cfg, threads=2),
      jaxsim.run_grid_arrays_trained(dtr, st, daso_theta=theta,
                                     daso_cfg=cfg, devices=8))
check("static-daso random arm",
      jaxsim.run_grid_arrays_static_daso(dtr, "random+daso",
                                         daso_theta=theta, daso_cfg=cfg,
                                         threads=2),
      jaxsim.run_grid_arrays_static_daso(dtr, "random+daso",
                                         daso_theta=theta, daso_cfg=cfg,
                                         devices=8))

# devices="auto" takes the whole fleet; bogus counts raise
out = jaxsim.run_grid_arrays(traces, devices="auto")
assert len(out) == 5
try:
    jaxsim.run_grid_arrays(traces, devices=9)
except ValueError as e:
    print("devices=9 rejected:", e)
else:
    raise AssertionError("devices=9 should have raised")
print("GRID_SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_grid_matches_thread_chunk():
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "GRID_SHARDED_OK" in r.stdout, r.stdout[-2000:]
