"""SoA simulator ≙ legacy per-object simulator, trace for trace.

``repro.env.simulator.EdgeSim`` (structure-of-arrays kernels) must
reproduce ``repro.env.legacy_sim.LegacyEdgeSim`` (the seed's per-object
loops) exactly: the kernels perform the same elementwise float ops in the
same accumulation order, so we assert bit-equality, not allclose —
finished-task sets, response times, accuracies (same RNG draw order),
per-interval energy, utilization, and worker-completion census.
"""
import numpy as np
import pytest

from repro.core.splitplace import BestFitPlacer
from repro.env.legacy_sim import LegacyEdgeSim
from repro.env.simulator import EdgeSim
from repro.env.workload import COMPRESSED, LAYER, SEMANTIC, Task


def run_trace(cls, decisions_of, n_intervals, lam, seed, substeps,
              ram_squeeze=1.0):
    """Drive one sim class through a BestFit trace; returns trace record."""
    sim = cls(lam=lam, seed=seed, substeps=substeps)
    if ram_squeeze != 1.0:
        sim._ram = sim._ram * ram_squeeze
    placer = BestFitPlacer()
    rec = dict(finished=[], energy=[], util=[], pwt=[], waits=[],
               active=[], waiting=[])
    for t in range(n_intervals):
        tasks = sim.new_interval_tasks()
        sim.admit(tasks, decisions_of(tasks))
        sim.apply_placement(placer.place(sim))
        stats = sim.advance()
        rec["finished"] += [(tk.id, tk.app, tk.decision, tk.response_s,
                             tk.accuracy, tk.wait_s) for tk in stats.finished]
        rec["energy"].append(stats.energy_j)
        rec["util"].append(stats.cpu_util.copy())
        rec["pwt"].append(stats.per_worker_tasks.copy())
        rec["active"].append(stats.num_active)
        rec["waiting"].append(stats.num_waiting)
    return rec


def assert_traces_equal(a, b):
    assert a["finished"] == b["finished"]      # ids, responses, accuracies
    assert a["energy"] == b["energy"]
    assert a["active"] == b["active"]
    assert a["waiting"] == b["waiting"]
    np.testing.assert_array_equal(np.stack(a["util"]), np.stack(b["util"]))
    np.testing.assert_array_equal(np.stack(a["pwt"]), np.stack(b["pwt"]))


@pytest.mark.parametrize("seed", [0, 3])
def test_mixed_decisions_trace_matches(seed):
    """All three split decisions interleaved, moderate load."""
    dec = lambda tasks: [i % 3 for i in range(len(tasks))]
    a = run_trace(LegacyEdgeSim, dec, n_intervals=12, lam=6.0, seed=seed,
                  substeps=10)
    b = run_trace(EdgeSim, dec, n_intervals=12, lam=6.0, seed=seed,
                  substeps=10)
    assert len(a["finished"]) > 0
    assert_traces_equal(a, b)


def test_overload_waiting_and_swap_paths_match():
    """High λ + squeezed RAM exercises placement failure (waiting tasks)
    and RAM over-subscription (swap slowdown)."""
    dec = lambda tasks: [COMPRESSED] * len(tasks)
    kw = dict(n_intervals=10, lam=12.0, seed=1, substeps=8, ram_squeeze=0.5)
    a = run_trace(LegacyEdgeSim, dec, **kw)
    b = run_trace(EdgeSim, dec, **kw)
    assert max(a["waiting"] + a["active"]) > 0
    assert_traces_equal(a, b)


@pytest.mark.parametrize("decision", [LAYER, SEMANTIC, COMPRESSED])
def test_single_decision_traces_match(decision):
    dec = lambda tasks: [decision] * len(tasks)
    a = run_trace(LegacyEdgeSim, dec, n_intervals=8, lam=4.0, seed=2,
                  substeps=6)
    b = run_trace(EdgeSim, dec, n_intervals=8, lam=4.0, seed=2, substeps=6)
    assert_traces_equal(a, b)


def test_manual_chain_progression_matches():
    """Hand-placed layer chain: stage advance + transfer timing parity."""
    def one(cls):
        sim = cls(lam=0, seed=0, substeps=10)
        t = Task(id=0, app=1, batch=40000, sla_s=1e9, arrival_s=0.0)
        sim.gen.realize(t, LAYER)
        sim.active.append(t)
        t.placed = True
        for i, f in enumerate(t.fragments):
            f.worker = (i * 7) % sim.cluster.n
        stages, times = [], []
        for _ in range(60):
            sim.advance()
            stages.append(t.stage)
            if t.done:
                return stages, t.response_s
        raise AssertionError("chain did not finish")

    sa, ra = one(LegacyEdgeSim)
    sb, rb = one(EdgeSim)
    assert sa == sb
    assert ra == rb


def test_append_before_realize_still_simulated():
    """A task appended to ``active`` before ``realize`` must not be
    adopted in its fragment-less state and dropped from the dynamics."""
    sim = EdgeSim(lam=0, seed=0, substeps=10)
    t = Task(id=0, app=0, batch=40000, sla_s=1e9, arrival_s=0.0)
    sim.active.append(t)
    sim.apply_placement({})              # adoption attempt pre-realize
    sim.advance()
    sim.gen.realize(t, SEMANTIC)
    t.placed = True
    for i, f in enumerate(t.fragments):
        f.worker = i
    for _ in range(60):
        sim.advance()
        if t.done:
            break
    assert t.done and t.response_s > 0


def test_finished_tasks_readable_after_compaction():
    """Caller-held finished Task objects must keep their final state once
    the store compacts their rows away (no aliasing of reused rows)."""
    sim = EdgeSim(lam=8.0, seed=5, substeps=6)
    placer = BestFitPlacer()
    finished = []
    for _ in range(30):        # enough turnover to trigger compaction
        tasks = sim.new_interval_tasks()
        sim.admit(tasks, [i % 3 for i in range(len(tasks))])
        sim.apply_placement(placer.place(sim))
        finished += sim.advance().finished
    assert len(finished) > 64
    snap = [(t.id, t.response_s, t.accuracy) for t in finished]
    for t, (tid, resp, acc) in zip(finished, snap):
        assert t.done                        # stable final state
        assert t.id == tid and t.response_s == resp and t.accuracy == acc
        assert all(f.done for f in t.fragments)


def test_state_features_match():
    """Placer observation parity after a few mixed intervals."""
    def one(cls):
        sim = cls(lam=5.0, seed=4, substeps=6)
        placer = BestFitPlacer()
        for _ in range(5):
            tasks = sim.new_interval_tasks()
            sim.admit(tasks, [i % 3 for i in range(len(tasks))])
            sim.apply_placement(placer.place(sim))
            sim.advance()
        return sim.state_features()

    np.testing.assert_array_equal(one(LegacyEdgeSim), one(EdgeSim))
