"""Streaming serve driver contracts (``repro.env.jaxsim.stream``).

Five pin groups, mirroring docs/ARCHITECTURE.md's "Streaming serve"
section:

  * **chunked-replay parity** — splitting a frozen compiled trace into
    chunk tapes and threading the carry through consecutive jitted
    chunk calls reproduces the one-shot ``run_trace_engine`` episode at
    the standard rtol=1e-4 summary contract, for the static, learned
    (deploy) and Gillis engine families — including a non-dividing
    chunk size (remainder chunk) and the fold_in(key, t) engines, which
    only pass if hooks see the ABSOLUTE interval index;
  * **counted-not-silent admission** — arrivals beyond the feeder tape
    width are dropped host-side into ``feeder_overflow``, arrivals
    beyond free ring capacity are dropped in-kernel into ``dropped``,
    and the serving report's ledger balances exactly:
    offered == fed + feeder_overflow, admitted == fed − dropped,
    admitted == finished + live;
  * **one compile per chunk shape** — a multi-chunk soak costs exactly
    one runner-cache miss; every later equal-size chunk is a hit
    (``driver.cache_stats()`` deltas);
  * **LRU-bounded cache** — the runner cache evicts beyond
    ``set_cache_limit``, ``cache_stats()`` reports evictions,
    re-compiling an evicted key raises the eviction ledger warning, and
    ``clear_cache()`` resets everything;
  * **donated carry** — on backends that pass the donation probe the
    chunk-to-chunk carry is donated (the previous chunk's buffers die
    in place; asserted inside ``run_chunk``) and stays device-resident
    between chunks — no host round-trip mid-stream.
"""
import numpy as np
import pytest

RTOL, ATOL = 1e-4, 1e-9


def _mab_state():
    import jax.numpy as jnp

    from repro.core import mab
    return mab.init_state(3)._replace(
        R=jnp.array([700.0, 1800.0, 3500.0], jnp.float32),
        Q=jnp.array([[0.8, 0.6], [0.3, 0.7]], jnp.float32),
        N=jnp.array([[20.0, 10.0], [5.0, 25.0]], jnp.float32),
        eps=jnp.asarray(0.4, jnp.float32),
        rho=jnp.asarray(0.06, jnp.float32),
        t=jnp.asarray(40, jnp.int32))


def _summaries_close(ref, got, ctx):
    assert set(ref) == set(got), ctx
    for k in ref:
        rv, gv = ref[k], got[k]
        if isinstance(rv, np.ndarray):
            np.testing.assert_allclose(gv, rv, rtol=RTOL, atol=ATOL,
                                       err_msg=f"{ctx}: {k}")
        elif isinstance(rv, float):
            assert np.isclose(gv, rv, rtol=RTOL, atol=ATOL), \
                f"{ctx}: {k} one-shot={rv!r} chunked={gv!r}"
        else:
            assert rv == gv, f"{ctx}: {k} one-shot={rv!r} chunked={gv!r}"


# ------------------------------------------------ chunked-replay parity


def test_replay_parity_static():
    from repro.env import jaxsim
    from repro.env.jaxsim import stream
    dec = jaxsim.make_static_decider("mc")
    tr = jaxsim.compile_trace(dec, lam=4.0, seed=0, n_intervals=12,
                              substeps=4)
    eng = jaxsim.engines.StaticEngine()
    ref = jaxsim.run_trace_engine(eng, tr, ())
    # 12 intervals in chunks of 5: two full chunks + a remainder chunk,
    # so the carry crosses two boundaries and one odd shape
    got = stream.replay_stream(eng, tr, (), chunk_intervals=5)
    _summaries_close(ref, got, "static")


def test_replay_parity_learned():
    """MABDeployEngine's UCB counters ride the carry across chunk
    boundaries; decision parity requires the global interval index."""
    from repro.env import jaxsim
    from repro.env.jaxsim import driver, stream
    st = _mab_state()
    tr = jaxsim.compile_trace_dual(lam=4.0, seed=3, n_intervals=12,
                                   substeps=4)
    eng = jaxsim.engines.MABDeployEngine(mab_hp=tuple(driver.MAB_HP))
    ref = jaxsim.run_trace_engine(eng, tr, driver._deploy_es(st, ()))
    got = stream.replay_stream(eng, tr, driver._deploy_es(st, ()),
                               chunk_intervals=5)
    _summaries_close(ref, got, "learned")


def test_replay_parity_gillis():
    """GillisEngine draws its ε-greedy bits from fold_in(key, t) — the
    strictest chunk-boundary contract: any chunk-local t would pass
    static parity but desync every decision here."""
    from repro.env import jaxsim
    from repro.env.jaxsim import driver, stream
    from repro.env.workload import COMPRESSED, LAYER
    tr = jaxsim.compile_trace_dual(lam=4.0, seed=2, n_intervals=12,
                                   substeps=4,
                                   variants=(LAYER, COMPRESSED))
    eng = jaxsim.engines.GillisEngine(gillis_hp=tuple(driver.GILLIS_HP))

    def es0():
        return driver._gillis_es(None, driver.trace_train_key(2), 3,
                                 driver.GILLIS_HP[0])

    ref = jaxsim.run_trace_engine(eng, tr, es0())
    got = stream.replay_stream(eng, tr, es0(), chunk_intervals=5)
    _summaries_close(ref, got, "gillis")


def test_replay_series_matches_episode_series():
    """The concatenated chunk telemetry series equals the one-shot
    interval-mode series row for row."""
    from repro.env import jaxsim
    from repro.env.jaxsim import stream
    dec = jaxsim.make_static_decider("bestfit-rr")
    tr = jaxsim.compile_trace(dec, lam=4.0, seed=1, n_intervals=9,
                              substeps=3)
    eng = jaxsim.engines.StaticEngine()
    ref = jaxsim.run_trace_engine(eng, tr, (), telemetry="interval")
    got = stream.replay_stream(eng, tr, (), chunk_intervals=4,
                               collect_series=True)
    assert got["telemetry"]["cols"] == ref["telemetry"]["cols"]
    np.testing.assert_allclose(got["telemetry"]["series"],
                               ref["telemetry"]["series"],
                               rtol=RTOL, atol=ATOL)


# ------------------------------------------- counted-not-silent admission


def _serve(policy="mc", **kw):
    from repro.env.jaxsim import stream
    eng, es0, fkw = stream.make_stream_policy(policy)
    feeder_kw = {k: kw.pop(k) for k in ("max_arrivals",) if k in kw}
    feeder = stream.StreamFeeder(lam=kw.pop("lam", 6.0), seed=0,
                                 interval_s=300.0, substeps=3,
                                 **feeder_kw, **fkw)
    rep = stream.serve(eng, es0, feeder, **kw)
    return rep


def _check_ledger(rep):
    assert rep["offered"] == rep["fed"] + rep["feeder_overflow"], rep
    assert rep["admitted"] == rep["fed"] - rep["dropped"], rep
    assert rep["admitted"] == rep["finished"] + rep["live"], rep


def test_serve_accounting_balances():
    rep = _serve(chunk_intervals=6, max_active=128, target_tasks=150,
                 window_intervals=24)
    _check_ledger(rep)
    assert rep["feeder_overflow"] == 0 and rep["dropped"] == 0
    assert rep["finished"] > 0
    assert rep["rolling"]["qps"] > 0
    assert 0 <= rep["rolling"]["violation_rate"] <= 1


def test_feeder_overflow_counted():
    """A tape too narrow for the burst drops host-side — counted, and
    the ledger still balances (nothing silently vanishes)."""
    rep = _serve(chunk_intervals=6, max_active=128, target_tasks=150,
                 window_intervals=24, max_arrivals=3)
    _check_ledger(rep)
    assert rep["feeder_overflow"] > 0


def test_ring_capacity_drops_counted():
    """A ring smaller than the live-task population drops in-kernel —
    counted in ``dropped``, and the ledger still balances."""
    rep = _serve(chunk_intervals=6, max_active=8, target_tasks=150,
                 window_intervals=24)
    _check_ledger(rep)
    assert rep["dropped"] > 0
    assert rep["max_occupancy"] <= 8


# ------------------------------------------ one compile per chunk shape


def test_soak_compiles_once_per_chunk_shape():
    from repro.env import jaxsim
    from repro.env.jaxsim import stream
    eng, es0, fkw = stream.make_stream_policy("mc")
    feeder = stream.StreamFeeder(lam=5.0, seed=1, interval_s=300.0,
                                 substeps=3, **fkw)
    before = jaxsim.cache_stats()
    rep = stream.serve(eng, es0, feeder, chunk_intervals=4,
                       max_active=128, target_tasks=400,
                       window_intervals=16)
    after = jaxsim.cache_stats()
    assert rep["n_chunks"] >= 3
    # serve emits fixed-size chunks only → exactly one stream compile,
    # every subsequent chunk a cache hit
    assert after["misses"] == before["misses"] + 1
    assert after["hits"] == before["hits"] + rep["n_chunks"] - 1


# ---------------------------------------------------- LRU-bounded cache


def test_cache_lru_eviction_and_clear():
    from repro.env import jaxsim
    from repro.obs import RunLedger, use_ledger
    dec = jaxsim.make_static_decider("mc")
    eng = jaxsim.engines.StaticEngine(name="stream-lru-test")
    trs = [jaxsim.compile_trace(dec, lam=3.0, seed=0, n_intervals=n,
                                substeps=3) for n in (3, 4, 5)]
    jaxsim.clear_cache()
    old = jaxsim.set_cache_limit(2)
    led = RunLedger("lru-test")
    try:
        with use_ledger(led):
            for tr in trs:                    # 3 keys into a 2-slot cache
                jaxsim.run_trace_engine(eng, tr, ())
            stats = jaxsim.cache_stats()
            assert stats["limit"] == 2
            assert stats["size"] <= 2
            assert stats["evictions"] >= 1
            # the oldest key was evicted; re-running it recompiles and
            # raises the eviction-specific ledger warning
            before = jaxsim.cache_stats()
            jaxsim.run_trace_engine(eng, trs[0], ())
            assert jaxsim.cache_stats()["misses"] == before["misses"] + 1
        warns = [ln for ln in led.to_lines() if ln["kind"] == "warning"]
        assert any("evicted" in w["message"] for w in warns), warns
        counts = [ln for ln in led.to_lines() if ln["kind"] == "counters"]
        assert any(c["counters"].get("runner_cache.eviction")
                   for c in counts), counts
    finally:
        jaxsim.set_cache_limit(old)
    jaxsim.clear_cache()
    stats = jaxsim.cache_stats()
    assert stats == {"hits": 0, "misses": 0, "evictions": 0, "size": 0,
                     "limit": old, "keys": {}}


def test_cache_limit_validation():
    from repro.env import jaxsim
    with pytest.raises(ValueError, match="cache limit"):
        jaxsim.set_cache_limit(0)


def test_lru_recency_order():
    """A hit refreshes recency: touching the oldest key makes the
    middle key the eviction victim."""
    from repro.env import jaxsim
    dec = jaxsim.make_static_decider("mc")
    eng = jaxsim.engines.StaticEngine(name="stream-lru-order-test")
    trs = [jaxsim.compile_trace(dec, lam=3.0, seed=0, n_intervals=n,
                                substeps=3) for n in (3, 4, 5)]
    jaxsim.clear_cache()
    old = jaxsim.set_cache_limit(2)
    try:
        jaxsim.run_trace_engine(eng, trs[0], ())    # A
        jaxsim.run_trace_engine(eng, trs[1], ())    # B
        jaxsim.run_trace_engine(eng, trs[0], ())    # hit A → B is LRU
        jaxsim.run_trace_engine(eng, trs[2], ())    # C evicts B
        before = jaxsim.cache_stats()
        jaxsim.run_trace_engine(eng, trs[0], ())    # A still cached
        after = jaxsim.cache_stats()
        assert after["hits"] == before["hits"] + 1
        assert after["misses"] == before["misses"]
    finally:
        jaxsim.set_cache_limit(old)
        jaxsim.clear_cache()


# -------------------------------------------------------- donated carry


def test_carry_donated_and_device_resident():
    """On a donation-capable backend (the CPU backend passes the probe
    on current jax) the previous carry dies in place after each chunk —
    ``run_chunk`` itself asserts that — and the live carry never leaves
    the device between chunks."""
    import jax

    from repro.env import jaxsim
    from repro.env.jaxsim import driver, stream
    dec = jaxsim.make_static_decider("mc")
    tr = jaxsim.compile_trace(dec, lam=4.0, seed=0, n_intervals=8,
                              substeps=3)
    eng = jaxsim.engines.StaticEngine()
    r = stream.StreamRunner(eng, (), interval_s=tr.interval_s,
                            substeps=tr.substeps, max_active=64)
    assert r.donated == driver._donation_ok()
    for _, tape in jaxsim.chunk_tapes(tr, 4):
        r.run_chunk(tape)                 # donation asserted inside
    for leaf in jax.tree_util.tree_leaves(r.carry):
        assert isinstance(leaf, jax.Array) and not leaf.is_deleted()
    s = r.summary(tr.n_intervals)
    assert s["tasks_completed"] >= 0


def test_chunk_tapes_validation():
    from repro.env import jaxsim
    dec = jaxsim.make_static_decider("mc")
    tr = jaxsim.compile_trace(dec, lam=3.0, seed=0, n_intervals=4,
                              substeps=3)
    with pytest.raises(ValueError, match="chunk_intervals"):
        list(jaxsim.chunk_tapes(tr, 0))
    chunks = list(jaxsim.chunk_tapes(tr, 3))
    assert [t0 for t0, _ in chunks] == [0, 3]
    assert chunks[-1][1]["valid"].shape[0] == 1   # remainder chunk


def test_feeder_requires_exactly_one_mode():
    from repro.env import jaxsim
    from repro.env.jaxsim import stream
    with pytest.raises(ValueError, match="exactly one"):
        stream.StreamFeeder(lam=3.0)
    with pytest.raises(ValueError, match="exactly one"):
        stream.StreamFeeder(lam=3.0,
                            decider=jaxsim.make_static_decider("mc"),
                            variants=jaxsim.engines.MAB_VARIANTS)
