"""Fuzzed state parity for the fused substep-physics kernel.

Three implementations of one interval of substep physics must agree:

  * ``repro.kernels.ref.edge_substep_ref`` — the pure-jnp scan
    reference;
  * ``repro.kernels.edge_substep.edge_substep`` — the Pallas kernel
    (interpret mode on CPU), same formulas expressed as an in-kernel
    ``fori_loop`` over VMEM-resident refs;
  * the driver's inline XLA path (``kernels.run_substeps``) — checked
    end-to-end through ``run_trace_arrays(substep_impl=...)``.

The fuzz draws synthetic-but-consistent slot states from a seeded
``numpy.random.RandomState`` (no hypothesis dependency): padding
fragment columns are born done with ``worker=-1``, stages are within
``[0, F]``, and every physical quantity is positive — the same
invariants ``arrays.init_state`` guarantees.  float64 carries must
match at ``rtol=1e-12`` (they are the same operations in the same
order, so in practice they match bitwise).
"""
from __future__ import annotations

import numpy as np
import pytest

K, F, N = 12, 4, 6
SUBSTEPS, DT = 7, 1.5
KW = dict(substeps=SUBSTEPS, dt=DT, swap_slowdown=0.5, nic_cap=50.0)


def _rand_inputs(rng: np.random.RandomState):
    """One consistent fuzzed (carries + statics) input set."""
    nfrag = rng.randint(1, F + 1, K).astype(np.int32)
    colpad = np.arange(F)[None, :] >= nfrag[:, None]     # padding columns
    done = rng.rand(K, F) < 0.35
    done |= colpad
    worker = rng.randint(0, N, (K, F)).astype(np.int32)
    worker[colpad] = -1
    placed = rng.rand(K) < 0.8
    worker[~placed] = -1
    task_done = done.all(axis=1) & (rng.rand(K) < 0.5)
    stage = np.minimum(done.argmin(axis=1).astype(np.int32), nfrag - 1)
    stage[done.all(axis=1)] = nfrag[done.all(axis=1)]
    args = dict(
        instr=np.where(done, 0.0, rng.uniform(1e3, 5e4, (K, F))),
        done=done,
        transfer=np.where(done, 0.0, rng.uniform(0.0, 30.0, (K, F))),
        stage=stage,
        task_done=task_done,
        resp=np.where(task_done, rng.uniform(1.0, 50.0, K), 0.0),
        now=np.asarray([rng.uniform(0.0, 900.0)]),
        metrics=rng.uniform(0.0, 10.0, 9),
        worker=worker,
        ram_task=rng.uniform(0.5, 8.0, K),
        out_bytes=rng.uniform(0.1, 40.0, (K, F)),
        nfrag=nfrag,
        chain=rng.rand(K) < 0.5,
        placed=placed,
        sla=rng.uniform(5.0, 60.0, K),
        arrival=rng.uniform(0.0, 600.0, K),
        acc_t=rng.uniform(0.5, 1.0, K),
        wait_s=rng.uniform(0.0, 10.0, K),
        decision=rng.randint(0, 3, K).astype(np.int32),
        bw_mult=rng.uniform(0.3, 1.0, N),
        mips=rng.uniform(2e3, 8e3, N),
        cap=rng.uniform(4.0, 16.0, N),
        net_bw=rng.uniform(100.0, 1000.0, N),
    )
    from repro.kernels.edge_substep import CARRY_NAMES, STATIC_NAMES
    return [args[k] for k in CARRY_NAMES + STATIC_NAMES]


@pytest.mark.parametrize("seed", range(8))
def test_pallas_matches_ref_fuzzed(seed):
    from jax.experimental import enable_x64

    from repro.kernels.edge_substep import OUT_NAMES, edge_substep
    from repro.kernels.ref import edge_substep_ref
    args = _rand_inputs(np.random.RandomState(seed))
    with enable_x64():       # f64 carries, the driver's execution regime
        outs_p = edge_substep(*args, **KW, interpret=True)
        outs_r = edge_substep_ref(*args, **KW)
    for name, p, r in zip(OUT_NAMES, outs_p, outs_r):
        p, r = np.asarray(p), np.asarray(r)
        assert p.shape == r.shape and p.dtype == r.dtype, name
        if p.dtype == bool:
            assert (p == r).all(), name
        else:
            np.testing.assert_allclose(p, r, rtol=1e-12, atol=0,
                                       err_msg=name)


def test_pallas_under_vmap_matches_per_row():
    """The grid driver runs the kernel under vmap — the batching rule
    must agree with stacking per-row calls."""
    import jax
    from jax.experimental import enable_x64

    from repro.kernels.edge_substep import edge_substep

    rows = [_rand_inputs(np.random.RandomState(100 + i)) for i in range(3)]
    stacked = [np.stack(cols) for cols in zip(*rows)]
    f = lambda *a: edge_substep(*a, **KW, interpret=True)
    with enable_x64():
        outs_v = jax.vmap(f)(*stacked)
        for i, row in enumerate(rows):
            outs_1 = f(*row)
            for v, o in zip(outs_v, outs_1):
                np.testing.assert_allclose(np.asarray(v)[i],
                                           np.asarray(o), rtol=1e-12,
                                           atol=0)


def test_driver_impls_agree_end_to_end():
    """substep_impl="xla" / "ref" / "pallas" must produce the same trace
    summaries through the real driver (dense vs incremental census is
    exact: the counts are small integers)."""
    from repro.env.jaxsim import compile_trace, make_static_decider, \
        run_trace_arrays
    tr = compile_trace(make_static_decider("mc"), lam=5.0, seed=3,
                       n_intervals=4, substeps=4)
    outs = {impl: run_trace_arrays(tr, substep_impl=impl)
            for impl in ("xla", "ref", "pallas")}
    base = outs["xla"]
    for impl in ("ref", "pallas"):
        for k in base:
            assert np.isclose(base[k], outs[impl][k], rtol=1e-9,
                              atol=1e-12), \
                f"{impl} {k}: xla={base[k]!r} {impl}={outs[impl][k]!r}"


def test_substep_impl_env_var(monkeypatch):
    """JAXSIM_SUBSTEP_IMPL is the process-wide default; an explicit
    argument wins over it; junk values raise."""
    from repro.env.jaxsim.driver import _resolve_substep_impl
    monkeypatch.delenv("JAXSIM_SUBSTEP_IMPL", raising=False)
    assert _resolve_substep_impl(None) == "xla"
    monkeypatch.setenv("JAXSIM_SUBSTEP_IMPL", "pallas")
    assert _resolve_substep_impl(None) == "pallas"
    assert _resolve_substep_impl("ref") == "ref"
    with pytest.raises(ValueError):
        _resolve_substep_impl("vulkan")
    monkeypatch.setenv("JAXSIM_SUBSTEP_IMPL", "vulkan")
    with pytest.raises(ValueError):
        _resolve_substep_impl(None)
