"""Sharding rules + a real (subprocess) multi-device dry-run test."""
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import get_config
from repro.launch.flopcount import count_fn


def test_flopcount_matmul_exact():
    import jax.numpy as jnp
    a = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((16, 4), jnp.float32)
    flops, _ = count_fn(lambda x, y: x @ y, a, b)
    assert flops == 2 * 8 * 16 * 4


def test_flopcount_scales_scan_by_length():
    import jax.numpy as jnp

    def body(c, x):
        return c @ x, ()

    def f(c, xs):
        out, _ = jax.lax.scan(body, c, xs)
        return out

    c = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    xs = jax.ShapeDtypeStruct((10, 4, 4), jnp.float32)
    flops, _ = count_fn(f, c, xs)
    assert flops == 10 * 2 * 4 * 4 * 4


def test_param_pspec_divisibility_rules():
    """Sharding rules never request a non-divisible partition."""
    from jax.sharding import PartitionSpec
    os.environ.setdefault("XLA_FLAGS", "")
    from repro.launch import sharding, specs
    # fake mesh shape info without 512 devices: use mesh abstract API
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ["musicgen-medium", "qwen2-vl-7b", "qwen2-moe-a2.7b"]:
        cfg = get_config(arch)
        p_shape = specs.params_specs(cfg)
        shards = sharding.params_shardings(mesh, cfg, p_shape)
        jax.tree.map(lambda s: None, shards)   # builds without error


@pytest.mark.slow
def test_dryrun_subprocess_tinyllama():
    """End-to-end: 512 fake devices, 16x16 mesh, lower+compile succeeds and
    reports roofline terms (run in a subprocess so this process keeps 1
    device)."""
    out = "/tmp/test_dryrun_tiny.json"
    if os.path.exists(out):
        os.remove(out)
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "tinyllama-1.1b", "--shape", "train_4k", "--mesh", "single",
         "--out", out],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    d = json.load(open(out))
    assert d["chips"] == 256
    assert d["memory"]["peak_gb"] < 16.0          # fits HBM
    assert d["roofline"]["compute_s"] > 0
    assert d["collective_bytes_per_device"] > 0
    assert 0.05 < d["useful_flops_ratio"] <= 1.5


def test_local_device_count_is_one():
    """Smoke tests must not see the dry-run's 512 forced devices.  The
    sharded-grid CI leg forces a small host fleet of its own via
    XLA_FLAGS — honor that count instead of pinning 1."""
    import re
    m = re.search(r"xla_force_host_platform_device_count=(\d+)",
                  os.environ.get("XLA_FLAGS", ""))
    want = int(m.group(1)) if m else 1
    assert jax.local_device_count() == want


def test_param_pspec_expected_specs():
    """Regression-pin the sharding rules for key weights per family."""
    import jax.numpy as jnp
    from jax.sharding import AbstractMesh, PartitionSpec as P
    from repro.launch import sharding, specs
    # AbstractMesh carries the real production shape without 256 devices.
    # Signature differs across jax versions: >=0.5 takes (axis_sizes,
    # axis_names), 0.4.x takes a tuple of (name, size) pairs.
    try:
        mesh = AbstractMesh((16, 16), ("data", "model"))
    except TypeError:
        mesh = AbstractMesh((("data", 16), ("model", 16)))

    def spec_of(cfg, pred):
        p_shape = specs.params_specs(cfg)
        found = {}
        def visit(path, leaf):
            name = sharding._path_str(path)
            if pred(name):
                found[name] = sharding.param_pspec(mesh, cfg, path, leaf)
        jax.tree_util.tree_map_with_path(visit, p_shape)
        return found

    # dense: q heads TP, embed vocab TP + d FSDP
    cfg = get_config("llama3-405b")
    s = spec_of(cfg, lambda n: n == "embed" or n.endswith("b0/attn/wq"))
    assert s["embed"] == P("model", ("data",))
    assert s["body/b0/attn/wq"] == P(None, ("data",), "model", None)
    # GQA kv heads (8) don't divide model=16 -> no head TP on wk
    s = spec_of(cfg, lambda n: n.endswith("b0/attn/wk"))
    assert s["body/b0/attn/wk"] == P(None, ("data",), None, None)
    # kimi experts are expert-parallel
    cfg = get_config("kimi-k2-1t-a32b")
    s = spec_of(cfg, lambda n: n.endswith("moe/w_up"))
    assert s["body/b0/moe/w_up"] == P(None, "model", ("data",), None)
    # qwen2-moe: 60 experts don't divide 16 -> TP inside the expert
    cfg = get_config("qwen2-moe-a2.7b")
    s = spec_of(cfg, lambda n: n.endswith("moe/w_up"))
    assert s["body/b0/moe/w_up"] == P(None, None, ("data",), "model")
    # musicgen: 24 heads -> no head TP, MLP hidden TP survives
    cfg = get_config("musicgen-medium")
    s = spec_of(cfg, lambda n: n.endswith("b0/attn/wq") or n.endswith("b0/mlp/w_up"))
    assert s["body/b0/attn/wq"][2] is None
    assert s["body/b0/mlp/w_up"][2] == "model"
