"""Real split networks (Fig. 1/2 semantics): layer split is EXACT,
semantic split trades accuracy for per-branch size."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import splitnets as sn
from repro.data.pipeline import synthetic_classification


@pytest.fixture(scope="module")
def trained():
    cfg = sn.ClassifierConfig(input_dim=64, num_classes=10, hidden=128,
                              depth=3)
    x, y = synthetic_classification("mnist", 4000, seed=0)
    x = x[:, :64]
    params = sn.train_classifier(jax.random.PRNGKey(0), cfg, x, y, steps=250)
    return cfg, params, x, y


def test_layer_split_is_exact(trained):
    cfg, params, x, y = trained
    full = sn.mlp_apply(params, jnp.asarray(x[:256]))
    for n_frag in (1, 2, 3, 4):
        frags = sn.layer_split(params, n_frag)
        out = sn.layer_split_apply(frags, jnp.asarray(x[:256]))
        np.testing.assert_array_equal(np.asarray(full), np.asarray(out))


def test_layer_split_fragment_structure(trained):
    cfg, params, _, _ = trained
    frags = sn.layer_split(params, 2)
    assert sum(len(f) for f in frags) == len(params)
    flops = sn.fragment_flops(frags)
    assert all(f > 0 for f in flops)


def test_semantic_split_accuracy_tradeoff(trained):
    """Semantic branches: measurable accuracy drop, smaller per-branch
    params — the trade-off SplitPlace exploits."""
    cfg, params, x, y = trained
    acc_full = sn.accuracy(params, x, y)
    branches, groups = sn.train_semantic_split(
        jax.random.PRNGKey(1), cfg, x, y, num_branches=2, steps=250)
    logits = sn.semantic_split_apply(branches, groups, jnp.asarray(x))
    acc_sem = float((jnp.argmax(logits, -1) == jnp.asarray(y)).mean())
    assert acc_full > 0.6                      # the task is learnable
    assert acc_sem > 0.3                       # branches still informative
    assert acc_sem <= acc_full + 0.02          # semantic does not beat full
    # per-branch parameter count strictly smaller than the full model
    n_full = sum(int(np.prod(p["w"].shape)) for p in params)
    n_branch = max(sum(int(np.prod(p["w"].shape)) for p in b)
                   for b in branches)
    assert n_branch < 0.55 * n_full


def test_class_groups_partition():
    groups = sn.class_groups(100, 4)
    flat = [c for g in groups for c in g]
    assert flat == list(range(100))
    assert len(groups) == 4
