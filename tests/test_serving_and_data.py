"""Serving plans/engine (TPU-native SplitPlace) + data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import APPS, TokenPipeline, synthetic_classification
from repro.models import forward, init_params
from repro.serving.engine import Request, SplitPlaceEngine
from repro.serving.plans import (branch_forward, pipeline_forward,
                                 plan_cost_model, PlanSpec, LAYER_PLAN,
                                 SEMANTIC_PLAN, stage_bounds)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_pipeline_forward_is_exact(small_model):
    """Layer-split plan must reproduce the monolithic forward exactly."""
    cfg, params = small_model
    tok = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (2, 16)), jnp.int32)
    batch = {"tokens": tok}
    want, _ = forward(params, batch, cfg)
    for stages in (1, 2, 3):
        got = pipeline_forward(params, batch, cfg, stages)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


def test_branch_forward_is_approximate_but_sane(small_model):
    """Semantic plan: different from monolithic (fidelity cost) but still
    produces finite, calibrated-scale logits."""
    cfg, params = small_model
    tok = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (2, 16)), jnp.int32)
    batch = {"tokens": tok}
    mono, _ = forward(params, batch, cfg)
    semantic = branch_forward(params, batch, cfg, num_branches=2)
    assert semantic.shape == mono.shape
    assert bool(jnp.isfinite(semantic).all())
    assert float(jnp.abs(semantic - mono).max()) > 1e-3   # genuinely approx


def test_stage_bounds_partition():
    b = stage_bounds(22, 3)
    assert b[0][0] == 0 and b[-1][1] == 22
    assert all(lo < hi for lo, hi in b)


def test_plan_cost_model_orders_latency():
    cfg = get_config("tinyllama-1.1b")
    lat_layer = plan_cost_model(cfg, PlanSpec(LAYER_PLAN, num_stages=4),
                                seq=128, batch=4)
    lat_sem = plan_cost_model(cfg, PlanSpec(SEMANTIC_PLAN, num_branches=4),
                              seq=128, batch=4)
    assert lat_sem < lat_layer


def test_engine_serves_and_learns(small_model):
    cfg, params = small_model
    eng = SplitPlaceEngine(params, cfg, num_stages=2, num_branches=2)
    rng = np.random.RandomState(0)
    tok = rng.randint(0, cfg.vocab_size, (1, 32)).astype(np.int32)
    eng.warmup(tok)
    results = []
    for i in range(6):
        deadline = 10.0 if i % 2 == 0 else 1e-4   # loose / impossible
        results.append(eng.serve(Request(tokens=tok, deadline_s=deadline)))
    assert all(0.0 <= r.fidelity <= 1.0 for r in results)
    assert any(r.met_deadline for r in results)
    assert float(eng.state.N.sum()) == len(results)
    # layer-pipeline fidelity is exact, semantic is not
    for r in results:
        if r.plan == LAYER_PLAN:
            assert r.fidelity == 1.0


def test_token_pipeline_learnable_and_deterministic():
    a = TokenPipeline(1000, 32, 4, seed=3).next_batch()
    b = TokenPipeline(1000, 32, 4, seed=3).next_batch()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 32)
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()
    assert a["tokens"].max() < 1000


def test_token_pipeline_codebooks():
    b = TokenPipeline(100, 8, 2, seed=0, num_codebooks=4).next_batch()
    assert b["tokens"].shape == (2, 8, 4)


def test_synthetic_classification_separable():
    for app in APPS:
        x, y = synthetic_classification(app, 256, seed=1)
        assert x.shape[0] == 256 and y.max() < APPS[app].num_classes
