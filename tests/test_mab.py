"""Unit tests for the MAB decision module (paper eqs. 2–9)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mab


def test_response_estimate_ema():
    s = mab.init_state(3)
    apps = jnp.array([0, 0, 1], jnp.int32)
    resp = jnp.array([10.0, 20.0, 5.0])
    was_layer = jnp.array([True, True, False])
    s = mab.update_response_estimates(s, apps, resp, was_layer, phi=0.9)
    # R0: 0 -> 0.9*10 = 9 -> 0.9*20 + 0.1*9 = 18.9 (eq. 2, newest weighted)
    np.testing.assert_allclose(float(s.R[0]), 18.9, rtol=1e-5)
    assert float(s.R[1]) == 0.0          # semantic task must not update R


def test_context_classification():
    s = mab.init_state(2)._replace(R=jnp.array([100.0, 50.0]))
    assert int(mab.context_of(s, 120.0, 0)) == mab.HIGH
    assert int(mab.context_of(s, 80.0, 0)) == mab.LOW
    assert int(mab.context_of(s, 80.0, 1)) == mab.HIGH


def test_interval_rewards_bucketing():
    s = mab.init_state(1)._replace(R=jnp.array([10.0]))
    apps = jnp.zeros(4, jnp.int32)
    sla = jnp.array([20.0, 20.0, 5.0, 5.0])      # 2 high, 2 low
    resp = jnp.array([15.0, 25.0, 4.0, 6.0])     # met, miss, met, miss
    acc = jnp.array([0.9, 0.9, 0.8, 0.8])
    dec = jnp.array([0, 0, 1, 1], jnp.int32)     # layer high, semantic low
    O, cnt = mab.interval_rewards(s, apps, sla, resp, acc, dec)
    np.testing.assert_allclose(np.asarray(cnt),
                               [[2, 0], [0, 2]])
    # high/layer: ((1+0.9)+(0+0.9))/2/2 = 0.7
    np.testing.assert_allclose(float(O[mab.HIGH, mab.LAYER]), 0.7, rtol=1e-6)
    # low/semantic: ((1+0.8)/2 + (0+0.8)/2)/2 = 0.65
    np.testing.assert_allclose(float(O[mab.LOW, mab.SEMANTIC]), 0.65,
                               rtol=1e-6)


def test_rbed_eps_decay_and_rho_increment():
    s = mab.init_state(1, eps0=1.0, rho0=0.05)
    O = jnp.full((2, 2), 0.8)
    cnt = jnp.ones((2, 2))
    s2 = mab.rbed_update(s, O, cnt, k=0.1)
    np.testing.assert_allclose(float(s2.eps), 0.9, rtol=1e-6)
    np.testing.assert_allclose(float(s2.rho), 0.055, rtol=1e-6)
    # below threshold: no change
    s3 = mab.rbed_update(s2._replace(rho=jnp.asarray(0.9)), O, cnt)
    assert float(s3.eps) == float(s2.eps)


def test_ucb_prefers_undervisited_then_converges():
    s = mab.init_state(1)._replace(
        R=jnp.array([10.0]),
        Q=jnp.array([[0.9, 0.8], [0.2, 0.85]]),
        N=jnp.array([[100.0, 1.0], [1.0, 100.0]]),
        t=jnp.asarray(50, jnp.int32))
    # high ctx: Q favors layer but semantic nearly unvisited -> UCB explores
    d, ctx = mab.decide_ucb(s, 20.0, 0, c=2.0)
    assert int(ctx) == mab.HIGH and int(d) == mab.SEMANTIC
    # with small c, exploit Q
    d, _ = mab.decide_ucb(s, 20.0, 0, c=0.01)
    assert int(d) == mab.LAYER
    # low ctx exploits semantic
    d, ctx = mab.decide_ucb(s, 5.0, 0, c=0.01)
    assert int(ctx) == mab.LOW and int(d) == mab.SEMANTIC


def test_epsilon_greedy_is_random_at_eps1():
    s = mab.init_state(1)._replace(Q=jnp.array([[1.0, 0.0], [1.0, 0.0]]))
    keys = jax.random.split(jax.random.PRNGKey(0), 200)
    ds = [int(mab.decide_train(s, k, 20.0, 0)[0]) for k in keys]
    frac = np.mean(ds)
    assert 0.3 < frac < 0.7                      # coin flips despite Q gap


def test_end_of_interval_full_update():
    s = mab.init_state(3)
    apps = jnp.array([0, 1, 2], jnp.int32)
    sla = jnp.array([10.0, 10.0, 10.0])
    resp = jnp.array([5.0, 15.0, 8.0])
    acc = jnp.array([0.9, 0.85, 0.8])
    dec = jnp.array([0, 1, 0], jnp.int32)
    s2 = mab.end_of_interval(s, apps, sla, resp, acc, dec)
    assert int(s2.t) == 2
    assert float(s2.N.sum()) == 3.0
    assert float(s2.R[0]) > 0 and float(s2.R[1]) == 0.0


def test_argmax_tie_break_pinned_masked_vs_dense():
    """Tie-handling contract for the in-kernel deciders: with exactly
    equal Q (and UCB bonus) values, `jnp.argmax` must resolve to the
    LOWEST arm index (LAYER) — and the padded/batched (masked) paths the
    jitted kernel uses must agree row-for-row with the dense scalar
    calls the host replay makes, so train-mode decisions can't silently
    diverge between kernel and replay at ε/Q boundaries."""
    # all-equal Q and N: both arms tie in Q AND in UCB bonus
    s = mab.init_state(2)._replace(
        R=jnp.array([10.0, 10.0]),
        Q=jnp.full((2, 2), 0.5, jnp.float32),
        N=jnp.full((2, 2), 4.0, jnp.float32),
        t=jnp.asarray(9, jnp.int32))
    sla = jnp.array([20.0, 5.0, 20.0, 5.0], jnp.float32)
    app = jnp.array([0, 0, 1, 1], jnp.int32)
    # dense scalar path (host replay order)
    dense = [int(mab.decide_ucb(s, sla[i], app[i], 0.5)[0])
             for i in range(4)]
    assert dense == [mab.LAYER] * 4          # ties -> lowest index
    # batched path (kernel) over the padded width must match the prefix
    batch, _ = mab.decide_ucb_batch(s, jnp.concatenate([sla, sla]),
                                    jnp.concatenate([app, app]), 0.5)
    assert [int(d) for d in batch[:4]] == dense


def test_decide_train_rows_prefix_stable_and_eps_boundaries():
    """The key-threaded train decisions must be (a) prefix-stable in the
    padded row count — the kernel calls `decide_train_rows` on (A,)
    padded arrays, the replay on the dense valid prefix, and both must
    draw identical bits per real row — and (b) deterministic at the ε
    boundaries: ε=0 is pure greedy (argmax, ties -> LAYER), ε=1 is a
    pure coin flip independent of Q."""
    key_t = jax.random.fold_in(jax.random.PRNGKey(7), 3)
    sla = jnp.linspace(5.0, 40.0, 12).astype(jnp.float32)
    app = jnp.arange(12, dtype=jnp.int32) % 3
    s = mab.init_state(3)._replace(R=jnp.array([20.0, 20.0, 20.0]),
                                   eps=jnp.asarray(0.5, jnp.float32))
    d_full, _ = mab.decide_train_rows(s, key_t, sla, app)
    for n in (1, 4, 7, 12):
        d_pre, _ = mab.decide_train_rows(s, key_t, sla[:n], app[:n])
        np.testing.assert_array_equal(np.asarray(d_pre),
                                      np.asarray(d_full[:n]))
    # eps=0: greedy, and with tied all-zero Q the argmax pins to LAYER
    d0, _ = mab.decide_train_rows(
        s._replace(eps=jnp.asarray(0.0, jnp.float32)), key_t, sla, app)
    assert set(np.asarray(d0).tolist()) == {mab.LAYER}
    # eps=1: always the coin flip, regardless of a decisive Q gap
    s1 = s._replace(eps=jnp.asarray(1.0, jnp.float32),
                    Q=jnp.array([[1.0, 0.0], [1.0, 0.0]], jnp.float32))
    keys = jax.random.split(jax.random.PRNGKey(0), 64)
    ds = np.array([[int(mab.decide_train(s1, k, 20.0, 0)[0]) for k in keys]])
    assert 0.2 < ds.mean() < 0.8             # both arms despite Q gap


def test_end_of_interval_masked_matches_dense():
    """The masked array form (shared by the jitted kernel and its parity
    replay) must agree with the dense update on the masked-in rows and
    degrade to the empty-interval update (t += 1 only) on an all-False
    mask."""
    s = mab.init_state(3)._replace(R=jnp.array([10.0, 10.0, 10.0]))
    apps = jnp.array([0, 1, 2, 0], jnp.int32)
    sla = jnp.array([10.0, 10.0, 10.0, 99.0])
    resp = jnp.array([5.0, 15.0, 8.0, 1.0])
    acc = jnp.array([0.9, 0.85, 0.8, 0.1])
    dec = jnp.array([0, 1, 0, 1], jnp.int32)
    dense = mab.end_of_interval(s, apps[:3], sla[:3], resp[:3], acc[:3],
                                dec[:3])
    masked = mab.end_of_interval_masked(
        s, apps, sla, resp, acc, dec,
        jnp.array([True, True, True, False]))
    for a, b in zip(dense, masked):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    empty = mab.end_of_interval_masked(s, apps, sla, resp, acc, dec,
                                       jnp.zeros(4, bool))
    assert int(empty.t) == int(s.t) + 1
    np.testing.assert_array_equal(np.asarray(empty.Q), np.asarray(s.Q))
    assert float(empty.eps) == float(s.eps)
