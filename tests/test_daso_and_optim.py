"""DASO surrogate + placement optimization; optimizer substrate."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import daso
from repro.optim import optimizers as opt


def _cfg(w=4, c=3):
    return daso.DASOConfig(num_workers=w, max_containers=c,
                           state_features=2, hidden=32, depth=2,
                           place_iters=60, lr_place=0.3)


def test_surrogate_trains_to_low_mse():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    theta, opt_state = daso.make_trainer(cfg, key)
    n = 128
    xs = jax.random.normal(jax.random.PRNGKey(1), (n, daso.feature_size(cfg)))
    w_true = jax.random.normal(jax.random.PRNGKey(2),
                               (daso.feature_size(cfg),)) * 0.3
    ys = jnp.tanh(xs @ w_true)
    losses = []
    for _ in range(300):
        theta, opt_state, l = daso.train_epoch(cfg, theta, opt_state, xs, ys)
        losses.append(float(l))
    assert losses[-1] < 0.05 * losses[0]


def test_placement_gradient_ascent_improves_score():
    """eq. 12: the optimized placement must score >= the initial one."""
    cfg = _cfg()
    theta, _ = daso.make_trainer(cfg, jax.random.PRNGKey(3))
    state = jnp.zeros((cfg.num_workers, cfg.state_features))
    p0 = jax.random.normal(jax.random.PRNGKey(4),
                           (cfg.max_containers, cfg.num_workers))
    dec = jnp.zeros((cfg.max_containers,), jnp.int32)
    mask = jnp.ones((cfg.max_containers,))
    s0 = daso.surrogate_apply(theta, daso.pack_input(cfg, state, p0, dec, mask))
    p_opt, score, iters = daso.optimize_placement(cfg, theta, state, p0, dec,
                                                  mask)
    assert float(score) >= float(s0) - 1e-6
    assert int(iters) > 0
    a = daso.placement_to_assignment(p_opt, mask)
    assert a.shape == (cfg.max_containers,)
    assert ((np.asarray(a) >= 0) & (np.asarray(a) < cfg.num_workers)).all()


def test_decision_aware_input_differs():
    cfg = _cfg()
    blind = daso.DASOConfig(**{**cfg._asdict(), "decision_aware": False})
    state = jnp.ones((cfg.num_workers, cfg.state_features))
    p = jnp.zeros((cfg.max_containers, cfg.num_workers))
    mask = jnp.ones((cfg.max_containers,))
    d0 = jnp.zeros((cfg.max_containers,), jnp.int32)
    d1 = jnp.ones((cfg.max_containers,), jnp.int32)
    x0 = daso.pack_input(cfg, state, p, d0, mask)
    x1 = daso.pack_input(cfg, state, p, d1, mask)
    assert float(jnp.abs(x0 - x1).max()) > 0           # DASO sees decisions
    y0 = daso.pack_input(blind, state, p, d0, mask)
    y1 = daso.pack_input(blind, state, p, d1, mask)
    assert float(jnp.abs(y0 - y1).max()) == 0          # GOBI does not


def _quadratic_losses(update_fn, init_fn, steps=200, lr=0.05):
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = init_fn(params)
    for _ in range(steps):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state = update_fn(grads, state, params, lr)
    return float(jnp.abs(params["w"] - target).max())


def test_adamw_converges():
    err = _quadratic_losses(
        lambda g, s, p, lr: opt.adamw_update(g, s, p, lr, weight_decay=0.0),
        opt.adamw_init)
    assert err < 0.05


def test_adafactor_converges():
    err = _quadratic_losses(opt.adafactor_update, opt.adafactor_init,
                            steps=400, lr=0.05)
    assert err < 0.1


def test_adafactor_factored_state_is_small():
    params = {"w": jnp.zeros((64, 128))}
    st = opt.adafactor_init(params)
    assert st.vr["w"].shape == (64,)
    assert st.vc["w"].shape == (128,)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 10.0}
    clipped, n = opt.clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(opt.global_norm(clipped)), 1.0,
                               rtol=1e-5)
    assert float(n) == 20.0


def test_warmup_cosine_schedule():
    assert float(opt.warmup_cosine(0, 1.0, 10, 100)) < 0.2
    assert float(opt.warmup_cosine(10, 1.0, 10, 100)) > 0.9
    assert float(opt.warmup_cosine(100, 1.0, 10, 100)) < 0.2
