"""Property-based differential fuzzing: ``backend="jax"`` vs EdgeSim.

Every generated case draws one configuration — worker fleet, arrival
rate λ, RAM/MIPS capacity scales, mobility/workload seed, handcrafted
MAB state (incl. ε/UCB hyperparameters) and DASO surrogate — runs it
through the jitted backend AND the host ``EdgeSim`` replay oracle, and
asserts the cross-backend allclose(rtol=1e-4) contract on every summary
metric (plus the final MAB scalars and, in train mode, the finetuned
DASO theta).  Three oracle pairs are covered:

  * **static** — ``run_trace_arrays`` vs ``replay_trace_edgesim``;
  * **deploy** — ``run_trace_arrays_learned`` vs
    ``replay_trace_edgesim_learned`` (online UCB MAB ± frozen DASO);
  * **train**  — ``run_trace_arrays_trained`` vs
    ``replay_trace_edgesim_trained`` (ε-greedy MAB + in-kernel DASO
    finetuning);
  * **gobi**   — the deploy pair with ``decision_aware=False`` (the
    decision-blind GOBI surrogate ablation; DASO always on);
  * **gillis** — ``run_trace_arrays_gillis`` vs
    ``replay_trace_edgesim_gillis`` (in-kernel contextual ε-greedy
    Q-learning over (LAYER, COMPRESSED) dual traces, incl. the final
    Q-table/ε).

Shape-determining parameters (intervals, substeps, cluster, DASO config,
MAB hyperparameters, slot capacity) are drawn from small *quantized*
pools so the fuzz run reuses a bounded set of compiled executables —
the point is to fuzz the physics/learning data space, not to pay an XLA
compile per example.

Two harnesses share one case-check:

  * a seeded self-contained generator (``test_differential_fuzz``) that
    always runs — ``DIFF_FUZZ_CASES`` (default 30; CI pins it) selects
    how many generated cases, e.g. ``DIFF_FUZZ_CASES=200`` for the full
    local sweep;
  * a `hypothesis` wrapper (``test_differential_hypothesis``) drawing
    from the same quantized space with shrinking, skipped when
    hypothesis isn't installed (see requirements-dev.txt).

Plus shrunk regression cases distilled from fuzz findings: RAM-pressure
repair parity (incl. train mode), ε-boundary decisions, and
capacity-overflow drop counting.
"""
import os

import numpy as np
import pytest

RTOL, ATOL = 1e-4, 1e-9

#: fixed slot capacity — big enough that no quantized config ever drops
#: (a dropped arrival would make the replay oracle incomparable); the
#: drop-counting contract is pinned separately below
MAX_ACTIVE = 160

#: quantized pools for every shape-/compile-relevant parameter
N_INTERVALS = (4, 6)
SUBSTEPS = (3, 4)
CLUSTERS = ("table3", "ram_squeeze", "slow_small")
MAB_HPS = ((0.5, 0.3, 0.3, 0.1),      # host MABDecider defaults
           (1.0, 0.3, 0.3, 0.1),      # exploratory UCB
           (0.05, 0.9, 0.5, 0.2),     # paper-φ, aggressive RBED
           (0.5, 0.3, 0.3, 0.0))      # k=0: RBED never decays ε
#: (alpha, beta, train_steps, place_min, train_min) — the lowered
#: cold-start gates make the short fuzz horizons exercise the
#: finetuned-surrogate ascent + train_epoch_weighted paths that the
#: host-default gates (32/8) reserve for long traces
TRAIN_HPS = ((0.5, 0.5, 4, 32, 8),    # host SurrogatePlacer defaults
             (0.5, 0.5, 2, 2, 1),     # gates open almost immediately
             (0.3, 0.7, 4, 4, 2))     # different eq.-10 weights
DASO_CFGS = ("small", "wide")
#: (eps0, lr, decay) pools for the Gillis arm — the boundary rows pin
#: pure-greedy (ε=0) and undecayed-coin (ε=1, decay=1) corners
GILLIS_HPS = ((0.5, 0.3, 0.995),      # host GillisDecider defaults
              (1.0, 0.5, 0.9),        # explore-heavy, fast decay
              (0.0, 0.3, 1.0),        # pure greedy forever
              (1.0, 1.0, 1.0))        # pure coin, lr=1 overwrites


def _cluster(name):
    from repro.env.cluster import make_cluster
    if name == "table3":
        return make_cluster()
    if name == "ram_squeeze":
        return make_cluster(ram_scale=0.45)
    # a smaller, slower, mobile-heavy fleet: different n AND physics
    return make_cluster(fleet=[("B2ms", 8), ("E2asv4", 4), ("B4ms", 4)],
                        compute_scale=0.7)


def _daso(name, n_workers, rng):
    import jax

    from repro.core import daso
    hidden, C = (16, 8) if name == "small" else (32, 16)
    cfg = daso.DASOConfig(num_workers=n_workers, max_containers=C,
                          state_features=4, hidden=hidden, depth=2,
                          place_iters=8)
    theta = daso.init_surrogate(jax.random.PRNGKey(int(rng.randint(2**31))),
                                cfg)
    return theta, cfg


def _mab_state(rng):
    """A random-but-plausible MABState: both contexts/arms reachable."""
    import jax.numpy as jnp

    from repro.core import mab
    return mab.init_state(3)._replace(
        R=jnp.asarray(rng.uniform(300.0, 4000.0, 3).astype(np.float32)),
        Q=jnp.asarray(rng.uniform(0.0, 1.0, (2, 2)).astype(np.float32)),
        N=jnp.asarray(rng.uniform(1.0, 40.0, (2, 2)).astype(np.float32)),
        eps=jnp.asarray(np.float32(rng.uniform(0.0, 1.0))),
        rho=jnp.asarray(np.float32(rng.uniform(0.02, 0.2))),
        t=jnp.asarray(int(rng.randint(1, 80)), jnp.int32))


def draw_case(case_seed: int) -> dict:
    """One fuzz configuration, fully determined by ``case_seed``."""
    rng = np.random.RandomState(case_seed)
    mode = ("static", "deploy", "train", "gillis", "gobi")[rng.randint(5)]
    case = {
        "mode": mode,
        "lam": float(np.round(rng.uniform(2.0, 9.0), 2)),
        "seed": int(rng.randint(10_000)),        # workload + mobility
        "n_intervals": int(N_INTERVALS[rng.randint(len(N_INTERVALS))]),
        "substeps": int(SUBSTEPS[rng.randint(len(SUBSTEPS))]),
        "cluster": CLUSTERS[rng.randint(len(CLUSTERS))],
        "mab_hp": MAB_HPS[rng.randint(len(MAB_HPS))],
        "mab_rng": int(rng.randint(2**31)),
        # the gobi ablation IS a surrogate config, so its daso draw
        # never lands on None
        "daso": (((None,) if mode != "gobi" else ())
                 + DASO_CFGS)[rng.randint(
                     (1 if mode != "gobi" else 0) + len(DASO_CFGS))],
    }
    if mode == "train":
        case["train_hp"] = TRAIN_HPS[rng.randint(len(TRAIN_HPS))]
    if mode == "static":
        case["policy"] = ("mc", "bestfit-rr", "bestfit-layer",
                          "bestfit-semantic",
                          "bestfit-threshold")[rng.randint(5)]
    if mode == "gillis":
        case["gillis_hp"] = GILLIS_HPS[rng.randint(len(GILLIS_HPS))]
    # drawn LAST so every earlier field matches the pre-telemetry
    # generator for the same case_seed (regression cases stay stable)
    case["telemetry"] = ("summary", "interval")[rng.randint(2)]
    return case


#: percentile estimates are compared at the documented binning error
#: bound, not rtol: the host oracle is exact while the kernel path bins
#: per-interval (see ``repro.env.metrics.series_percentiles``)
PCT_KEYS = tuple(f"p{q}_{m}_s" for q in (50, 95, 99)
                 for m in ("response", "wait"))


def assert_close(ref, jx, ctx):
    assert set(ref) == set(jx), f"{ctx}: key sets differ"
    for k in ref:
        if k in ("daso_theta", "gillis_q"):
            import jax
            for a, b in zip(jax.tree_util.tree_leaves(ref[k]),
                            jax.tree_util.tree_leaves(jx[k])):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=RTOL, atol=ATOL,
                    err_msg=f"{ctx}: {k}")
            continue
        if k == "telemetry":
            assert ref[k]["cols"] == jx[k]["cols"], \
                f"{ctx}: telemetry cols: {ref[k]['cols']} vs {jx[k]['cols']}"
            np.testing.assert_allclose(
                np.asarray(ref[k]["series"]), np.asarray(jx[k]["series"]),
                rtol=RTOL, atol=ATOL, err_msg=f"{ctx}: telemetry series")
            continue
        if k == "percentile_err_s":
            assert ref[k] >= 0.0 and jx[k] >= 0.0, f"{ctx}: {k}"
            continue
        if k in PCT_KEYS:
            bound = max(ref["percentile_err_s"], jx["percentile_err_s"])
            assert abs(ref[k] - jx[k]) <= bound + ATOL + RTOL * abs(ref[k]), \
                f"{ctx}: {k}: host={ref[k]!r} jax={jx[k]!r} bound={bound!r}"
            continue
        assert np.isclose(ref[k], jx[k], rtol=RTOL, atol=ATOL), \
            f"{ctx}: {k}: host={ref[k]!r} jax={jx[k]!r}"


def _gillis_state(rng):
    """A random-but-plausible Gillis carry: non-trivial Q, live ε."""
    return {"Q": rng.uniform(0.0, 1.0, (3, 2, 2)).astype(np.float64),
            "eps": np.float64(rng.uniform(0.0, 1.0))}


def check_case(case: dict):
    """Run one configuration through both backends and compare."""
    from repro.env import jaxsim
    cl = _cluster(case["cluster"])
    tel = case.get("telemetry", "summary")
    ctx = f"case={case!r}"
    if case["mode"] == "static":
        dec = jaxsim.make_static_decider(case["policy"])
        tr = jaxsim.compile_trace(
            dec, lam=case["lam"], seed=case["seed"],
            n_intervals=case["n_intervals"], substeps=case["substeps"],
            cluster=cl, max_arrivals=48)
        ref = jaxsim.replay_trace_edgesim(tr, cluster=cl, telemetry=tel)
        jx = jaxsim.run_trace_arrays(tr, cluster=cl, max_active=MAX_ACTIVE,
                                     telemetry=tel)
        assert jx["dropped_tasks"] == 0, ctx
        assert_close(ref, jx, ctx)
        return
    rng = np.random.RandomState(case["mab_rng"])
    if case["mode"] == "gillis":
        from repro.env.workload import COMPRESSED, LAYER
        st = _gillis_state(rng)
        tr = jaxsim.compile_trace_dual(
            lam=case["lam"], seed=case["seed"],
            n_intervals=case["n_intervals"], substeps=case["substeps"],
            cluster=cl, max_arrivals=48, variants=(LAYER, COMPRESSED))
        ref = jaxsim.replay_trace_edgesim_gillis(
            tr, gillis_state=st, cluster=cl, gillis_hp=case["gillis_hp"],
            telemetry=tel)
        jx = jaxsim.run_trace_arrays_gillis(
            tr, gillis_state=st, cluster=cl, max_active=MAX_ACTIVE,
            gillis_hp=case["gillis_hp"], telemetry=tel)
        assert jx["dropped_tasks"] == 0, ctx
        assert_close(ref, jx, ctx)
        return
    st = _mab_state(rng)
    theta = cfg = None
    if case["daso"] is not None:
        theta, cfg = _daso(case["daso"], cl.n, rng)
    if case["mode"] == "gobi":
        cfg = cfg._replace(decision_aware=False)
    tr = jaxsim.compile_trace_dual(
        lam=case["lam"], seed=case["seed"],
        n_intervals=case["n_intervals"], substeps=case["substeps"],
        cluster=cl, max_arrivals=48)
    if case["mode"] in ("deploy", "gobi"):
        ref = jaxsim.replay_trace_edgesim_learned(
            tr, st, daso_theta=theta, daso_cfg=cfg, cluster=cl,
            mab_hp=case["mab_hp"], telemetry=tel)
        jx = jaxsim.run_trace_arrays_learned(
            tr, st, daso_theta=theta, daso_cfg=cfg, cluster=cl,
            max_active=MAX_ACTIVE, mab_hp=case["mab_hp"], telemetry=tel)
    else:
        ref = jaxsim.replay_trace_edgesim_trained(
            tr, st, daso_theta=theta, daso_cfg=cfg, cluster=cl,
            mab_hp=case["mab_hp"], train_hp=case["train_hp"],
            telemetry=tel)
        jx = jaxsim.run_trace_arrays_trained(
            tr, st, daso_theta=theta, daso_cfg=cfg, cluster=cl,
            max_active=MAX_ACTIVE, mab_hp=case["mab_hp"],
            train_hp=case["train_hp"], telemetry=tel)
    assert jx["dropped_tasks"] == 0, ctx
    assert_close(ref, jx, ctx)


# ------------------------------------------------------------ fuzz drivers

N_CASES = int(os.environ.get("DIFF_FUZZ_CASES", "30"))


@pytest.mark.parametrize("case_seed", range(N_CASES))
def test_differential_fuzz(case_seed):
    check_case(draw_case(case_seed))


try:
    import hypothesis
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
    hypothesis.settings.register_profile(
        "ci", max_examples=20, deadline=None, derandomize=False,
        print_blob=True)
    hypothesis.settings.register_profile(
        "full", max_examples=200, deadline=None)
    hypothesis.settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_differential_hypothesis():
    """The same differential property under hypothesis shrinking: any
    failing case minimizes to a single integer seed whose full drawn
    configuration prints via ``draw_case``.  CI runs this with the
    bounded "ci" profile and a fixed ``--hypothesis-seed``."""
    @hypothesis.given(hst.integers(min_value=0, max_value=2**20))
    def prop(case_seed):
        check_case(draw_case(case_seed))

    prop()


# ------------------------------------------- shrunk regression fixtures
#
# Distilled corner cases the random sweep found or the kernels' fast
# paths make easy to get wrong; pinned here so they run in every tier-1
# invocation regardless of the fuzz budget.


def test_regression_ram_pressure_repair_static():
    """Squeezed RAM + high λ forces the sequential feasibility repair,
    placement failure (waiting tasks) and swap slowdown on both
    backends."""
    from repro.env import jaxsim
    from repro.env.cluster import make_cluster
    cl = make_cluster(ram_scale=0.3)
    dec = jaxsim.make_static_decider("mc")
    tr = jaxsim.compile_trace(dec, lam=14.0, seed=5, n_intervals=12,
                              substeps=4, cluster=cl)
    ref = jaxsim.replay_trace_edgesim(tr, cluster=cl)
    jx = jaxsim.run_trace_arrays(tr, cluster=cl)
    assert ref["wait_intervals"] > 0      # repair actually failed tasks
    assert_close(ref, jx, "ram-pressure static")


def test_regression_ram_pressure_repair_train():
    """RAM pressure under the TRAIN pipeline: the repair must rewrite
    the finetuned surrogate's requests identically on both backends
    (the learned stage's fallback path), while the training carry keeps
    advancing through the repaired placements."""
    from repro.env import jaxsim
    from repro.env.cluster import make_cluster
    rng = np.random.RandomState(11)
    cl = make_cluster(ram_scale=0.45)
    st = _mab_state(rng)
    theta, cfg = _daso("small", cl.n, rng)
    tr = jaxsim.compile_trace_dual(lam=11.0, seed=5, n_intervals=10,
                                   substeps=4, cluster=cl)
    hp = (0.5, 0.5, 2, 2, 1)      # gates open: repair sees ascended reqs
    ref = jaxsim.replay_trace_edgesim_trained(tr, st, daso_theta=theta,
                                              daso_cfg=cfg, cluster=cl,
                                              train_hp=hp)
    jx = jaxsim.run_trace_arrays_trained(tr, st, daso_theta=theta,
                                         daso_cfg=cfg, cluster=cl,
                                         train_hp=hp)
    assert ref["wait_intervals"] > 0 or ref["response_intervals"] > 1.0
    assert_close(ref, jx, "ram-pressure train")


def test_regression_eps_boundary_decisions():
    """ε=0 (pure greedy) and ε=1 (pure coin) train decisions both hold
    the parity contract — the boundary where a bernoulli tie could
    silently diverge between kernel and replay."""
    import jax.numpy as jnp

    from repro.env import jaxsim
    rng = np.random.RandomState(3)
    tr = jaxsim.compile_trace_dual(lam=5.0, seed=2, n_intervals=6,
                                   substeps=3)
    for eps in (0.0, 1.0):
        st = _mab_state(rng)._replace(eps=jnp.asarray(eps, jnp.float32))
        ref = jaxsim.replay_trace_edgesim_trained(tr, st)
        jx = jaxsim.run_trace_arrays_trained(tr, st)
        assert_close(ref, jx, f"eps={eps}")


def test_regression_gillis_eps_boundaries():
    """Gillis ε=0 (pure greedy over a tied all-zero Q) and ε=1 with
    decay=1 (pure coin forever) both hold the parity contract incl. the
    final Q-table — the argmax-tie and bernoulli-boundary corners."""
    from repro.env import jaxsim
    from repro.env.workload import COMPRESSED, LAYER
    tr = jaxsim.compile_trace_dual(lam=5.0, seed=2, n_intervals=6,
                                   substeps=3,
                                   variants=(LAYER, COMPRESSED))
    for hp in ((0.0, 0.3, 0.995), (1.0, 1.0, 1.0)):
        ref = jaxsim.replay_trace_edgesim_gillis(tr, gillis_hp=hp)
        jx = jaxsim.run_trace_arrays_gillis(tr, gillis_hp=hp)
        assert_close(ref, jx, f"gillis hp={hp}")


def test_regression_gillis_ram_pressure():
    """RAM pressure under the Gillis pipeline: compressed-arm tasks have
    the largest single-container footprints, so the feasibility repair
    rewrites BestFit requests while the Q-carry keeps updating."""
    from repro.env import jaxsim
    from repro.env.cluster import make_cluster
    from repro.env.workload import COMPRESSED, LAYER
    rng = np.random.RandomState(7)
    cl = make_cluster(ram_scale=0.4)
    st = _gillis_state(rng)
    tr = jaxsim.compile_trace_dual(lam=11.0, seed=5, n_intervals=10,
                                   substeps=4, cluster=cl,
                                   variants=(LAYER, COMPRESSED))
    ref = jaxsim.replay_trace_edgesim_gillis(tr, gillis_state=st,
                                             cluster=cl)
    jx = jaxsim.run_trace_arrays_gillis(tr, gillis_state=st, cluster=cl)
    assert ref["wait_intervals"] > 0 or ref["response_intervals"] > 1.0
    assert_close(ref, jx, "gillis ram pressure")


def test_regression_capacity_drop_counting():
    """Arrivals beyond ``max_active`` are dropped and *counted*, the
    count is deterministic, and batched grid rows agree with solo runs
    even while dropping."""
    from repro.env import jaxsim
    dec = jaxsim.make_static_decider("mc")
    tr = jaxsim.compile_trace(dec, lam=10.0, seed=1, n_intervals=8,
                              substeps=3)
    jx1 = jaxsim.run_trace_arrays(tr, max_active=8)
    jx2 = jaxsim.run_trace_arrays(tr, max_active=8)
    assert jx1["dropped_tasks"] > 0
    assert jx1 == jx2                      # drop accounting deterministic
    grid = jaxsim.run_grid_arrays([tr, tr], max_active=8, threads=1)
    for row in grid:
        for k in jx1:
            assert np.isclose(jx1[k], row[k], rtol=1e-12, atol=1e-12)
