"""Interval telemetry + run-ledger observability contracts.

Five pins, mirroring docs/ARCHITECTURE.md's "Observability" section:

  * **series parity** — the kernel's in-carry ``(T, C)`` telemetry
    series (``telemetry="interval"``) matches the host replay oracles
    column-for-column at the standard rtol=1e-4 contract, for the
    static, learned (deploy), trained and Gillis engine families, and
    the per-engine column layout agrees with the engine's
    ``telemetry_cols()`` declaration;
  * **zero-perturbation** — a ``telemetry="interval"`` run's summary
    scalars are identical (rtol=1e-12) to the ``"summary"`` run of the
    same trace: recording the series must not perturb the physics or
    the learning carries (the summary-mode interval body is verbatim,
    so this is near-bitwise);
  * **percentile bound** — kernel-path binned p50/p95/p99 estimates sit
    within the reported ``percentile_err_s`` of the host's exact
    percentiles, and the host's own error is exactly 0;
  * **runner-cache stats** — ``driver.cache_stats()`` counts hits and
    misses, and a same-engine recompile (same engine value, different
    static shapes) raises a ledger warning;
  * **RunLedger round-trip** — spans nest, JSONL dump/load round-trips,
    and ``tools/obs_report.py`` renders the cache and span sections the
    CI smoke step greps for.
"""
import json
import os
import sys

import numpy as np

_HERE = os.path.dirname(os.path.abspath(__file__))
RTOL, ATOL = 1e-4, 1e-9


def _mab_state():
    import jax.numpy as jnp

    from repro.core import mab
    return mab.init_state(3)._replace(
        R=jnp.array([700.0, 1800.0, 3500.0], jnp.float32),
        Q=jnp.array([[0.8, 0.6], [0.3, 0.7]], jnp.float32),
        N=jnp.array([[20.0, 10.0], [5.0, 25.0]], jnp.float32),
        eps=jnp.asarray(0.4, jnp.float32),
        rho=jnp.asarray(0.06, jnp.float32),
        t=jnp.asarray(40, jnp.int32))


def _series_close(ref, jx, ctx):
    assert ref["telemetry"]["cols"] == jx["telemetry"]["cols"], ctx
    rs = np.asarray(ref["telemetry"]["series"])
    js = np.asarray(jx["telemetry"]["series"])
    assert rs.shape == js.shape, f"{ctx}: {rs.shape} vs {js.shape}"
    for i, col in enumerate(ref["telemetry"]["cols"]):
        np.testing.assert_allclose(js[:, i], rs[:, i], rtol=RTOL,
                                   atol=ATOL, err_msg=f"{ctx}: col={col}")


# ------------------------------------------------- series parity oracles


def test_series_parity_static():
    from repro.env import jaxsim
    from repro.env.metrics import TELEMETRY_COLS
    dec = jaxsim.make_static_decider("bestfit-rr")
    tr = jaxsim.compile_trace(dec, lam=5.0, seed=0, n_intervals=8,
                              substeps=4)
    ref = jaxsim.replay_trace_edgesim(tr, telemetry="interval")
    jx = jaxsim.run_trace_arrays(tr, telemetry="interval")
    assert jx["telemetry"]["cols"] == list(TELEMETRY_COLS)
    assert np.asarray(jx["telemetry"]["series"]).shape == (8, 18)
    _series_close(ref, jx, "static")


def test_series_parity_learned():
    """Deploy-mode series carry the four MAB learning-signal columns,
    sampled at end-of-interval *after* the UCB feedback update."""
    from repro.env import jaxsim
    from repro.env.jaxsim.engines import MAB_TELEMETRY_COLS
    st = _mab_state()
    tr = jaxsim.compile_trace_dual(lam=5.0, seed=3, n_intervals=6,
                                   substeps=3)
    ref = jaxsim.replay_trace_edgesim_learned(tr, st, telemetry="interval")
    jx = jaxsim.run_trace_arrays_learned(tr, st, telemetry="interval")
    assert tuple(jx["telemetry"]["cols"][-4:]) == MAB_TELEMETRY_COLS
    _series_close(ref, jx, "learned")
    # the MAB decision counter actually advanced over the trace
    s = np.asarray(jx["telemetry"]["series"])
    n_dec = s[:, -2] + s[:, -1]            # mab_n_layer + mab_n_semantic
    assert n_dec[-1] > n_dec[0]


def test_series_parity_trained():
    """Train mode adds the DASO replay-window fill and window loss on
    top of the MAB columns; the loss column tracks the finetuned theta,
    so parity here pins the whole in-kernel training carry."""
    import jax

    from repro.core import daso
    from repro.env import jaxsim
    from repro.env.cluster import make_cluster
    cfg = daso.DASOConfig(num_workers=make_cluster().n, max_containers=8,
                          state_features=4, hidden=16, depth=2,
                          place_iters=8)
    theta = daso.init_surrogate(jax.random.PRNGKey(7), cfg)
    st = _mab_state()
    tr = jaxsim.compile_trace_dual(lam=5.0, seed=3, n_intervals=6,
                                   substeps=3)
    hp = (0.5, 0.5, 2, 2, 1)              # gates open on short horizons
    ref = jaxsim.replay_trace_edgesim_trained(
        tr, st, daso_theta=theta, daso_cfg=cfg, train_hp=hp,
        telemetry="interval")
    jx = jaxsim.run_trace_arrays_trained(
        tr, st, daso_theta=theta, daso_cfg=cfg, train_hp=hp,
        telemetry="interval")
    cols = jx["telemetry"]["cols"]
    assert cols[-2:] == ["daso_win_fill", "daso_last_loss"]
    _series_close(ref, jx, "trained")
    s = np.asarray(jx["telemetry"]["series"])
    fill = s[:, cols.index("daso_win_fill")]
    assert fill[-1] > 0 and np.all(np.diff(fill) >= 0)


def test_series_parity_gillis():
    from repro.env import jaxsim
    from repro.env.workload import COMPRESSED, LAYER
    tr = jaxsim.compile_trace_dual(lam=5.0, seed=2, n_intervals=6,
                                   substeps=3,
                                   variants=(LAYER, COMPRESSED))
    ref = jaxsim.replay_trace_edgesim_gillis(tr, telemetry="interval")
    jx = jaxsim.run_trace_arrays_gillis(tr, telemetry="interval")
    cols = jx["telemetry"]["cols"]
    assert cols[-3:] == ["gillis_eps", "gillis_q_min", "gillis_q_max"]
    _series_close(ref, jx, "gillis")
    # ε decays every interval (default decay < 1)
    eps = np.asarray(jx["telemetry"]["series"])[:, cols.index("gillis_eps")]
    assert np.all(np.diff(eps) < 0)


# --------------------------------------------- zero-perturbation + bound


def test_interval_mode_preserves_summary():
    """Turning the series on must not move any summary scalar: the
    interval-mode body duplicates the summary-mode hooks verbatim, so
    everything the ``"summary"`` run reports is reproduced at 1e-12."""
    from repro.env import jaxsim
    dec = jaxsim.make_static_decider("bestfit-rr")
    tr = jaxsim.compile_trace(dec, lam=5.0, seed=0, n_intervals=8,
                              substeps=4)
    off = jaxsim.run_trace_arrays(tr)
    on = jaxsim.run_trace_arrays(tr, telemetry="interval")
    for k, v in off.items():
        assert np.isclose(on[k], v, rtol=1e-12, atol=1e-12), \
            f"{k}: summary={v!r} interval={on[k]!r}"


def test_percentiles_within_reported_bound():
    from repro.env import jaxsim
    dec = jaxsim.make_static_decider("bestfit-rr")
    tr = jaxsim.compile_trace(dec, lam=5.0, seed=0, n_intervals=8,
                              substeps=4)
    ref = jaxsim.replay_trace_edgesim(tr, telemetry="interval")
    jx = jaxsim.run_trace_arrays(tr, telemetry="interval")
    assert ref["percentile_err_s"] == 0.0      # host path is exact
    assert jx["percentile_err_s"] >= 0.0
    for q in (50, 95, 99):
        for m in ("response", "wait"):
            k = f"p{q}_{m}_s"
            assert abs(ref[k] - jx[k]) <= jx["percentile_err_s"] + ATOL, \
                f"{k}: exact={ref[k]!r} binned={jx[k]!r} " \
                f"bound={jx['percentile_err_s']!r}"


def test_telemetry_knob_validation():
    import pytest

    from repro.env import jaxsim
    dec = jaxsim.make_static_decider("mc")
    tr = jaxsim.compile_trace(dec, lam=3.0, seed=0, n_intervals=4,
                              substeps=3)
    with pytest.raises(ValueError, match="telemetry"):
        jaxsim.run_trace_arrays(tr, telemetry="everything")


# ------------------------------------------------- cache + ledger layer


def test_cache_stats_hits_and_misses():
    from repro.env import jaxsim
    dec = jaxsim.make_static_decider("mc")
    tr = jaxsim.compile_trace(dec, lam=3.0, seed=0, n_intervals=4,
                              substeps=3)
    jaxsim.run_trace_arrays(tr)                    # warm (maybe a miss)
    before = jaxsim.cache_stats()
    jaxsim.run_trace_arrays(tr)                    # definitely a hit
    after = jaxsim.cache_stats()
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]
    assert after["size"] >= 1 and after["keys"]


def test_recompile_warning_on_ledger():
    """The same engine value compiled under two different static shapes
    is legitimate but worth flagging: the ledger records a warning and
    the per-engine key map shows both compilations."""
    from repro.env import jaxsim
    from repro.obs import RunLedger, use_ledger
    eng = jaxsim.engines.StaticEngine(name="telemetry-recompile-test")
    dec = jaxsim.make_static_decider("mc")
    tr1 = jaxsim.compile_trace(dec, lam=3.0, seed=0, n_intervals=4,
                               substeps=3)
    tr2 = jaxsim.compile_trace(dec, lam=3.0, seed=0, n_intervals=5,
                               substeps=3)
    led = RunLedger("recompile-test")
    with use_ledger(led):
        jaxsim.run_trace_engine(eng, tr1, ())
        jaxsim.run_trace_engine(eng, tr2, ())      # same engine, new key
    warns = [ln for ln in led.to_lines() if ln["kind"] == "warning"]
    assert any("recompile" in w["message"] for w in warns), warns


def test_ledger_round_trip_and_report(tmp_path):
    from repro.env import jaxsim
    from repro.obs import RunLedger, load_ledger_lines, use_ledger
    dec = jaxsim.make_static_decider("mc")
    tr = jaxsim.compile_trace(dec, lam=3.0, seed=0, n_intervals=4,
                              substeps=3)
    led = RunLedger("round-trip")
    led.stamp(telemetry="interval")
    with use_ledger(led):
        out = jaxsim.run_trace_arrays(tr, telemetry="interval")
        led.add_series("trace", out["telemetry"]["cols"],
                       out["telemetry"]["series"])
        led.add_cache_stats(jaxsim.cache_stats())
        led.count("unit_runs")
    path = tmp_path / "ledger.jsonl"
    led.dump(path)
    lines = load_ledger_lines(path)
    kinds = {ln["kind"] for ln in lines}
    assert {"meta", "span", "counters", "cache_stats",
            "series"} <= kinds
    spans = [ln for ln in lines if ln["kind"] == "span"]
    names = {s["name"] for s in spans}
    assert "dispatch" in names and "summarize" in names
    # every non-root span's parent is a recorded span id
    ids = {s["id"] for s in spans}
    assert all(s["parent"] in ids for s in spans
               if s["parent"] is not None)
    # the report renders the sections the CI smoke step greps for
    sys.path.insert(0, os.path.join(_HERE, os.pardir, "tools"))
    try:
        import obs_report
    finally:
        sys.path.pop(0)
    text = obs_report.render(lines)
    assert "== Span tree ==" in text
    assert "== Runner cache ==" in text
    assert "== Series: trace ==" in text
    assert "percentiles (binned" in text


def test_provenance_stamp_keys():
    from repro.obs import provenance_stamp
    st = provenance_stamp(telemetry="interval")
    for k in ("jax_version", "backend", "device_count", "device_kind",
              "cpu_count", "substep_impl", "devices"):
        assert k in st, st
    assert st["telemetry"] == "interval"
    assert json.dumps(st)                  # JSON-serializable
