import os

# NOTE: deliberately NOT setting xla_force_host_platform_device_count here —
# smoke tests and benches must see 1 device; only launch/dryrun.py forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
