"""Edge simulator + workload + metrics behaviour tests."""
import numpy as np

from repro.core.splitplace import run_experiment
from repro.env.cluster import make_cluster
from repro.env.mobility import MobilityModel
from repro.env.simulator import EdgeSim
from repro.env.workload import (COMPRESSED, LAYER, SEMANTIC, APP_PROFILES,
                                WorkloadGenerator)


def test_cluster_fleet_size_and_heterogeneity():
    c = make_cluster()
    assert c.n == 50
    assert len(set(c.mips())) >= 2
    assert (c.power(np.zeros(50)) > 0).all()
    assert (c.power(np.ones(50)) > c.power(np.zeros(50))).all()


def test_constrained_cluster_scales():
    base, half = make_cluster(), make_cluster(compute_scale=0.5)
    np.testing.assert_allclose(half.mips(), base.mips() * 0.5)


def test_mobility_is_deterministic_and_bounded():
    a = MobilityModel(10, [True] * 10, seed=3)
    b = MobilityModel(10, [True] * 10, seed=3)
    for _ in range(20):
        la, ba_ = a.step()
        lb, bb = b.step()
        np.testing.assert_allclose(la, lb)
        assert (la >= 1.0).all() and (ba_ <= 1.0).all() and (ba_ > 0).all()


def test_workload_realization_shapes():
    gen = WorkloadGenerator(lam=5, seed=0)
    tasks = []
    while not tasks:
        tasks = gen.arrivals(0.0)
    t = tasks[0]
    gen.realize(t, LAYER)
    assert len(t.fragments) == APP_PROFILES[t.app].n_frag
    assert t.chain
    t2 = tasks[0]
    gen2 = WorkloadGenerator(seed=1)
    t2 = gen2.arrivals(0.0) or None
    # semantic: parallel branches
    gen.realize(tasks[-1], SEMANTIC) if len(tasks) > 1 else None


def test_layer_chain_precedence():
    """A layer chain must execute strictly sequentially."""
    sim = EdgeSim(lam=0, seed=0, substeps=10)
    gen = sim.gen
    from repro.env.workload import Task
    t = Task(id=0, app=0, batch=40000, sla_s=1e9, arrival_s=0.0)
    gen.realize(t, LAYER)
    sim.active.append(t)
    t.placed = True
    for i, f in enumerate(t.fragments):
        f.worker = i % sim.cluster.n
    stages = []
    for _ in range(40):
        sim.advance()
        stages.append(t.stage)
        if t.done:
            break
    assert t.done
    assert stages == sorted(stages)          # stage only advances forward
    assert t.response_s > 0


def test_semantic_parallel_faster_than_layer():
    """With idle workers, parallel semantic branches finish before an
    equal-work sequential chain (the Fig. 2 latency gap)."""
    from repro.env.workload import Task

    def run_one(decision):
        sim = EdgeSim(lam=0, seed=0, substeps=30)
        t = Task(id=0, app=2, batch=40000, sla_s=1e9, arrival_s=0.0)
        sim.gen.realize(t, decision)
        sim.active.append(t)
        t.placed = True
        for i, f in enumerate(t.fragments):
            f.worker = i
        for _ in range(200):
            sim.advance()
            if t.done:
                return t.response_s
        raise AssertionError("did not finish")

    assert run_one(SEMANTIC) < 0.75 * run_one(LAYER)


def test_ram_feasibility_forces_wait():
    sim = EdgeSim(lam=0, seed=0)
    from repro.env.workload import Task
    t = Task(id=0, app=2, batch=64000, sla_s=1e9, arrival_s=0.0)
    sim.gen.realize(t, COMPRESSED)
    t.fragments[0].ram_mb = 1e9               # cannot fit anywhere
    sim.active.append(t)
    sim.apply_placement({(0, 0): 0})
    assert not t.placed


def test_run_experiment_end_to_end_metrics():
    r = run_experiment("mc", n_intervals=8, lam=4.0, seed=0, substeps=5)
    assert 0 <= r["sla_violations"] <= 1
    assert 0.8 <= r["accuracy"] <= 1.0
    assert r["energy_mwhr"] > 0
    assert 0 < r["fairness"] <= 1.0
    assert r["tasks_completed"] > 0


def test_policies_all_run():
    for pol in ["splitplace", "mab+gobi", "semantic+gobi", "layer+gobi",
                "random+daso", "gillis", "mc"]:
        r = run_experiment(pol, n_intervals=4, lam=3.0, seed=1, substeps=5,
                           train=(pol == "splitplace"))
        assert r["tasks_completed"] >= 0, pol
