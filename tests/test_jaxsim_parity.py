"""Jitted fixed-capacity simulator ≙ host EdgeSim, allclose on metrics.

The SoA↔legacy contract is bit-exact (``test_soa_equivalence``); the
jitted backend relaxes it to ``allclose(rtol=1e-4)`` on per-trace summary
metrics — reduction orders differ between its censuses and the host's
sequential ``bincount`` accumulation, but every elementwise float64
physics op matches.  Both sides consume the same compiled trace
(``repro.env.jaxsim.arrays.compile_trace``), replayed through the real
``EdgeSim`` by ``reference.replay_trace_edgesim``.
"""
import numpy as np
import pytest

from repro.env.cluster import make_cluster
from repro.env.jaxsim import (compile_trace, make_static_decider,
                              replay_trace_edgesim, run_grid_arrays,
                              run_trace_arrays)

RTOL, ATOL = 1e-4, 1e-9


def assert_summaries_close(ref, jx, rtol=RTOL, atol=ATOL):
    assert set(ref) == set(jx)
    for k in ref:
        assert np.isclose(ref[k], jx[k], rtol=rtol, atol=atol), \
            f"{k}: host={ref[k]!r} jax={jx[k]!r}"


@pytest.mark.parametrize("lam", [4.0, 9.0])
def test_bestfit_trace_parity_two_lams(lam):
    """20-interval mixed-decision BestFit trace at two arrival rates."""
    dec = make_static_decider("bestfit-rr")
    tr = compile_trace(dec, lam=lam, seed=0, n_intervals=20, substeps=10)
    ref = replay_trace_edgesim(tr)
    jx = run_trace_arrays(tr)
    assert ref["tasks_completed"] > 0
    assert jx["dropped_tasks"] == 0
    assert_summaries_close(ref, jx)


def test_parity_under_ram_pressure():
    """Squeezed RAM exercises the repair fallback, placement failure
    (waiting tasks) and swap-slowdown paths on both backends."""
    cl = make_cluster(ram_scale=0.35)
    dec = make_static_decider("mc")
    tr = compile_trace(dec, lam=14.0, seed=2, n_intervals=12, substeps=8,
                       cluster=cl)
    ref = replay_trace_edgesim(tr, cluster=cl)
    jx = run_trace_arrays(tr, cluster=cl)
    assert ref["wait_intervals"] > 0        # repair actually failed tasks
    assert_summaries_close(ref, jx)


def test_layer_chain_parity():
    """Pure layer-split load: stage precedence + activation transfers."""
    dec = make_static_decider("bestfit-layer")
    tr = compile_trace(dec, lam=8.0, seed=3, n_intervals=15, substeps=10)
    ref = replay_trace_edgesim(tr)
    jx = run_trace_arrays(tr)
    assert ref["layer_fraction"] == 1.0
    assert_summaries_close(ref, jx)


def test_vmap_grid_rows_match_solo_runs():
    """Batched grid row i must equal the solo run of trace i (vmap and
    chunked-thread dispatch change nothing numerically)."""
    dec = make_static_decider("bestfit-rr")
    traces = [compile_trace(dec, lam=lam, seed=s, n_intervals=10, substeps=6)
              for lam in (4.0, 8.0) for s in (0, 1)]
    grid = run_grid_arrays(traces, threads=2)
    for i, tr in enumerate(traces):
        solo = run_trace_arrays(tr)
        for k in solo:
            assert np.isclose(solo[k], grid[i][k], rtol=1e-12, atol=1e-12), \
                f"row {i} {k}: solo={solo[k]!r} grid={grid[i][k]!r}"


def test_capacity_overflow_is_counted_not_silent():
    """Arrivals beyond ``max_active`` must surface in ``dropped_tasks``."""
    dec = make_static_decider("mc")
    tr = compile_trace(dec, lam=10.0, seed=0, n_intervals=8, substeps=4)
    jx = run_trace_arrays(tr, max_active=8)
    assert jx["dropped_tasks"] > 0


def test_experiments_backend_jax_matches_batched():
    """`run_trace(backend='jax')` and `run_grid(backend='jax')` route
    through the same kernels and agree with run_grid_batched."""
    from repro.launch.experiments import run_grid, run_grid_batched, run_trace
    r1 = run_trace("mc", n_intervals=6, lam=4.0, seed=1, substeps=5,
                   backend="jax")
    recs = run_grid_batched("mc", seeds=(1,), lams=(4.0,), n_intervals=6,
                            substeps=5)
    assert np.isclose(r1["reward"], recs[0]["reward"], rtol=1e-12)
    grid = run_grid(("mc",), seeds=(1,), lams=(4.0,), n_intervals=6,
                    substeps=5, backend="jax")
    assert grid[0]["seed"] == 1 and grid[0]["lam"] == 4.0
    assert np.isclose(grid[0]["reward"], r1["reward"], rtol=1e-12)


# ------------------------------------------------- in-kernel learned policies
#
# The learned policies thread MABState (and the DASO surrogate) through
# the jitted interval carry; the reference is the same EdgeSim replay
# driven by the identical shared pure functions
# (reference.replay_trace_edgesim_learned).  States are handcrafted so
# the traces are deterministic and exercise both arms/contexts.


def _mab_state():
    import jax.numpy as jnp

    from repro.core import mab
    return mab.init_state(3)._replace(
        R=jnp.array([700.0, 1800.0, 3500.0], jnp.float32),
        Q=jnp.array([[0.8, 0.6], [0.3, 0.7]], jnp.float32),
        N=jnp.array([[20.0, 10.0], [5.0, 25.0]], jnp.float32),
        eps=jnp.asarray(0.4, jnp.float32),
        rho=jnp.asarray(0.06, jnp.float32),
        t=jnp.asarray(40, jnp.int32))


def _daso():
    import jax

    from repro.core import daso
    cfg = daso.DASOConfig(num_workers=50, max_containers=16,
                          state_features=4, hidden=32, depth=2,
                          place_iters=12)
    return daso.init_surrogate(jax.random.PRNGKey(0), cfg), cfg


def test_inkernel_mab_trace_parity():
    """Online UCB decisions + Algorithm-1 feedback in the kernel carry
    must reproduce the host replay: decisions, both split variants, and
    the final MAB scalars (eps/rho/t fingerprint the RBED trajectory)."""
    from repro.env.jaxsim import (compile_trace_dual,
                                  replay_trace_edgesim_learned,
                                  run_trace_arrays_learned)
    st = _mab_state()
    tr = compile_trace_dual(lam=5.0, seed=1, n_intervals=10, substeps=6)
    ref = replay_trace_edgesim_learned(tr, st)
    jx = run_trace_arrays_learned(tr, st)
    assert ref["tasks_completed"] > 0
    assert 0.0 < ref["layer_fraction"] < 1.0   # both arms actually taken
    assert jx["mab_t"] == tr.n_intervals + int(st.t)
    assert_summaries_close(ref, jx)


def test_inkernel_splitplace_parity():
    """MAB decider + array-form DASO placer (surrogate ascent, BestFit
    warm start, feasibility-repair fallback) vs the host replay."""
    from repro.env.jaxsim import (compile_trace_dual,
                                  replay_trace_edgesim_learned,
                                  run_trace_arrays_learned)
    st = _mab_state()
    theta, cfg = _daso()
    tr = compile_trace_dual(lam=5.0, seed=1, n_intervals=10, substeps=6)
    ref = replay_trace_edgesim_learned(tr, st, daso_theta=theta,
                                       daso_cfg=cfg)
    jx = run_trace_arrays_learned(tr, st, daso_theta=theta, daso_cfg=cfg)
    assert ref["tasks_completed"] > 0
    assert_summaries_close(ref, jx)


def test_learned_vmap_rows_match_solo():
    """Each grid row carries its own MABState copy: batched rows must be
    bit-close to solo runs, including the final carried-state scalars."""
    from repro.env.jaxsim import (compile_trace_dual,
                                  run_grid_arrays_learned,
                                  run_trace_arrays_learned)
    st = _mab_state()
    theta, cfg = _daso()
    traces = [compile_trace_dual(lam=lam, seed=s, n_intervals=6, substeps=4)
              for lam in (4.0, 7.0) for s in (0, 1)]
    grid = run_grid_arrays_learned(traces, st, daso_theta=theta,
                                   daso_cfg=cfg, threads=2)
    eps = {g["mab_eps"] for g in grid}
    assert len(eps) > 1          # per-row online trajectories diverged
    for i, tr in enumerate(traces):
        solo = run_trace_arrays_learned(tr, st, daso_theta=theta,
                                        daso_cfg=cfg)
        for k in solo:
            assert np.isclose(solo[k], grid[i][k], rtol=1e-12,
                              atol=1e-12), \
                f"row {i} {k}: solo={solo[k]!r} grid={grid[i][k]!r}"


def _theta_allclose(a, b, rtol=1e-4, atol=1e-9):
    import jax
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


def test_inkernel_mab_train_parity():
    """ε-greedy training decisions (eq. 6) + Algorithm-1 feedback in the
    kernel carry must reproduce the host replay: decisions drawn from
    the shared fold-in key choreography, both arms taken, and the final
    MAB scalars fingerprinting the RBED trajectory."""
    from repro.env.jaxsim import (compile_trace_dual,
                                  replay_trace_edgesim_trained,
                                  run_trace_arrays_trained)
    st = _mab_state()
    tr = compile_trace_dual(lam=5.0, seed=1, n_intervals=10, substeps=6)
    ref = replay_trace_edgesim_trained(tr, st)
    jx = run_trace_arrays_trained(tr, st)
    assert ref["tasks_completed"] > 0
    assert 0.0 < ref["layer_fraction"] < 1.0   # both arms actually taken
    assert jx["mab_t"] == tr.n_intervals + int(st.t)
    assert jx["mab_eps"] < float(st.eps)       # RBED ε-decay actually ran
    assert_summaries_close(ref, jx)


def test_inkernel_splitplace_train_parity():
    """The full §6.3 loop in-kernel — ε-greedy decisions + online DASO
    finetuning (replay-window appends, weighted train epochs, cold-start
    gates) — vs the host replay, incl. the finetuned theta pytree.  The
    trace is long enough that PLACE_MIN opens and the *finetuned*
    surrogate's ascended placements are actually deployed."""
    from repro.core import daso
    from repro.env.jaxsim import (compile_trace_dual,
                                  replay_trace_edgesim_trained,
                                  run_trace_arrays_trained)
    st = _mab_state()
    theta0, cfg = _daso()
    tr = compile_trace_dual(lam=5.0, seed=3, n_intervals=40, substeps=5)
    assert tr.n_intervals > daso.PLACE_MIN     # ascended placements used
    ref = replay_trace_edgesim_trained(tr, st, daso_theta=theta0,
                                       daso_cfg=cfg)
    jx = run_trace_arrays_trained(tr, st, daso_theta=theta0, daso_cfg=cfg)
    assert ref["tasks_completed"] > 0
    theta_ref = ref.pop("daso_theta")
    theta_jx = jx.pop("daso_theta")
    assert_summaries_close(ref, jx)
    _theta_allclose(theta_ref, theta_jx)
    # finetuning really moved the surrogate off the pretrain snapshot
    import jax
    moved = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                for a, b in zip(jax.tree_util.tree_leaves(theta_jx),
                                jax.tree_util.tree_leaves(theta0)))
    assert moved > 1e-4


def test_trained_vmap_rows_match_solo():
    """Each grid cell carries its own (MABState, theta, opt, window):
    batched rows must be bit-close to solo runs, incl. the finetuned
    theta, with per-cell ε-greedy keys diverging the trajectories."""
    from repro.env.jaxsim import (compile_trace_dual,
                                  run_grid_arrays_trained,
                                  run_trace_arrays_trained)
    st = _mab_state()
    theta, cfg = _daso()
    traces = [compile_trace_dual(lam=lam, seed=s, n_intervals=6, substeps=4)
              for lam in (4.0, 7.0) for s in (0, 1)]
    grid = run_grid_arrays_trained(traces, st, daso_theta=theta,
                                   daso_cfg=cfg, threads=2)
    eps = {g["mab_eps"] for g in grid}
    assert len(eps) > 1          # per-cell online trajectories diverged
    for i, tr in enumerate(traces):
        solo = run_trace_arrays_trained(tr, st, daso_theta=theta,
                                        daso_cfg=cfg)
        _theta_allclose(solo.pop("daso_theta"), grid[i].pop("daso_theta"),
                        rtol=1e-12, atol=1e-12)
        for k in solo:
            assert np.isclose(solo[k], grid[i][k], rtol=1e-12,
                              atol=1e-12), \
                f"row {i} {k}: solo={solo[k]!r} grid={grid[i][k]!r}"


def test_experiments_train_mode_backend_jax():
    """`run_grid_batched(mode='train')` routes the pretrain state into
    the training kernel and agrees with `run_trace(mode='train')`;
    static policies reject mode='train'."""
    from repro.launch.experiments import (PretrainState, run_grid_batched,
                                          run_trace)
    st = _mab_state()
    theta, cfg = _daso()
    pre = PretrainState(mab_state=st, daso_theta=theta, daso_cfg=cfg)
    recs = run_grid_batched("splitplace", seeds=(1,), lams=(5.0,),
                            n_intervals=6, substeps=4, pretrain_state=pre,
                            mode="train")
    r1 = run_trace("splitplace", n_intervals=6, lam=5.0, seed=1,
                   substeps=4, backend="jax", mode="train", mab_state=st,
                   daso_theta=theta, daso_cfg=cfg)
    assert np.isclose(r1["reward"], recs[0]["reward"], rtol=1e-12)
    # train-mode ε-greedy decisions differ from deploy-mode UCB ones
    r_dep = run_trace("splitplace", n_intervals=6, lam=5.0, seed=1,
                      substeps=4, backend="jax", mab_state=st,
                      daso_theta=theta, daso_cfg=cfg)
    assert r_dep["mab_eps"] != r1["mab_eps"] \
        or r_dep["layer_fraction"] != r1["layer_fraction"]
    with pytest.raises(ValueError):
        run_grid_batched("mc", seeds=(1,), lams=(5.0,), n_intervals=6,
                         substeps=4, mode="train")
    with pytest.raises(ValueError):
        run_trace("mc", n_intervals=6, lam=5.0, seed=1, substeps=4,
                  backend="jax", mode="train")


def test_inkernel_gillis_parity():
    """The Gillis baseline in the carry — contextual ε-greedy Q-learning
    between layer and compressed arms, per-interval ε-decay, sequential
    TD(0) updates — must reproduce the host replay, incl. the final
    Q-table and ε (the Q-trajectory fingerprint)."""
    from repro.env.jaxsim import (compile_trace_dual,
                                  replay_trace_edgesim_gillis,
                                  run_trace_arrays_gillis)
    from repro.env.workload import COMPRESSED, LAYER
    tr = compile_trace_dual(lam=5.0, seed=1, n_intervals=10, substeps=6,
                            variants=(LAYER, COMPRESSED))
    ref = replay_trace_edgesim_gillis(tr)
    jx = run_trace_arrays_gillis(tr)
    assert ref["tasks_completed"] > 0
    assert 0.0 < ref["layer_fraction"] < 1.0   # both arms actually taken
    q_ref = ref.pop("gillis_q")
    q_jx = jx.pop("gillis_q")
    np.testing.assert_allclose(q_jx, q_ref, rtol=RTOL, atol=ATOL)
    assert np.abs(q_jx).sum() > 0              # Q-updates actually ran
    assert jx["gillis_eps"] < 0.5              # ε-decay actually ran
    assert_summaries_close(ref, jx)


def test_gillis_vmap_rows_match_solo():
    """Each grid cell carries its own (Q, ε) copy: batched rows must be
    bit-close to solo runs, incl. the final Q-table."""
    from repro.env.jaxsim import (compile_trace_dual,
                                  run_grid_arrays_gillis,
                                  run_trace_arrays_gillis)
    from repro.env.workload import COMPRESSED, LAYER
    traces = [compile_trace_dual(lam=lam, seed=s, n_intervals=6,
                                 substeps=4, variants=(LAYER, COMPRESSED))
              for lam in (4.0, 7.0) for s in (0, 1)]
    grid = run_grid_arrays_gillis(traces, threads=2)
    assert len({tuple(np.ravel(g["gillis_q"])) for g in grid}) > 1
    for i, tr in enumerate(traces):
        solo = run_trace_arrays_gillis(tr)
        np.testing.assert_allclose(grid[i].pop("gillis_q"),
                                   solo.pop("gillis_q"),
                                   rtol=1e-12, atol=1e-12)
        for k in solo:
            assert np.isclose(solo[k], grid[i][k], rtol=1e-12,
                              atol=1e-12), \
                f"row {i} {k}: solo={solo[k]!r} grid={grid[i][k]!r}"


def test_inkernel_gobi_parity():
    """The decision-blind GOBI ablation (surrogate input's decision
    one-hot zeroed) vs the host replay under the SAME blind config —
    the ascent trajectories must coincide exactly like decision-aware
    DASO's."""
    from repro.env.jaxsim import (compile_trace_dual,
                                  replay_trace_edgesim_learned,
                                  run_trace_arrays_learned)
    st = _mab_state()
    theta, cfg = _daso()
    blind = cfg._replace(decision_aware=False)
    tr = compile_trace_dual(lam=5.0, seed=1, n_intervals=10, substeps=6)
    ref = replay_trace_edgesim_learned(tr, st, daso_theta=theta,
                                       daso_cfg=blind)
    jx = run_trace_arrays_learned(tr, st, daso_theta=theta, daso_cfg=blind)
    assert ref["tasks_completed"] > 0
    assert_summaries_close(ref, jx)


def test_experiments_gillis_gobi_backend_jax():
    """`run_grid_batched(policy='gillis'|'mab+gobi')` routes through the
    in-kernel engines and agrees with `run_trace(backend='jax')`;
    mab+gobi still demands the pretrained surrogate."""
    from repro.launch.experiments import (PretrainState, run_grid_batched,
                                          run_trace)
    recs = run_grid_batched("gillis", seeds=(1,), lams=(5.0,),
                            n_intervals=6, substeps=4)
    r1 = run_trace("gillis", n_intervals=6, lam=5.0, seed=1, substeps=4,
                   backend="jax")
    assert np.isclose(r1["reward"], recs[0]["reward"], rtol=1e-12)
    assert recs[0]["policy"] == "gillis"
    assert "gillis_eps" in recs[0]
    st = _mab_state()
    theta, cfg = _daso()
    pre = PretrainState(mab_state=st, daso_theta=theta, daso_cfg=cfg)
    recs_g = run_grid_batched("mab+gobi", seeds=(1,), lams=(5.0,),
                              n_intervals=6, substeps=4,
                              pretrain_state=pre)
    r2 = run_trace("mab+gobi", n_intervals=6, lam=5.0, seed=1, substeps=4,
                   backend="jax", mab_state=st, daso_theta=theta,
                   daso_cfg=cfg)
    assert np.isclose(r2["reward"], recs_g[0]["reward"], rtol=1e-12)
    with pytest.raises(ValueError):
        run_grid_batched("mab+gobi", seeds=(1,), lams=(5.0,),
                         n_intervals=6, substeps=4, mab_state=st)


def test_experiments_learned_backend_jax():
    """`run_grid_batched(policy='splitplace'|'mab')` routes the pretrain
    state into the kernel and agrees with `run_trace(backend='jax')`."""
    from repro.launch.experiments import (PretrainState, run_grid_batched,
                                          run_trace)
    st = _mab_state()
    theta, cfg = _daso()
    pre = PretrainState(mab_state=st, daso_theta=theta, daso_cfg=cfg)
    recs = run_grid_batched("splitplace", seeds=(1,), lams=(5.0,),
                            n_intervals=6, substeps=4, pretrain_state=pre)
    r1 = run_trace("splitplace", n_intervals=6, lam=5.0, seed=1,
                   substeps=4, backend="jax", mab_state=st,
                   daso_theta=theta, daso_cfg=cfg)
    assert np.isclose(r1["reward"], recs[0]["reward"], rtol=1e-12)
    recs_mab = run_grid_batched("mab", seeds=(1,), lams=(5.0,),
                                n_intervals=6, substeps=4,
                                pretrain_state=pre)
    assert recs_mab[0]["policy"] == "mab"
    with pytest.raises(ValueError):
        run_grid_batched("splitplace", seeds=(1,), lams=(5.0,),
                         n_intervals=6, substeps=4, mab_state=st)


def test_static_daso_arms_parity():
    """The three static-decider surrogate arms — one dual-trace engine:
    fixed LAYER/SEMANTIC decisions (or the prefix-stable fold-in random
    decider) feeding the frozen DASO placer, decision-blind for the GOBI
    arms — vs the host replay with the identical shared pure functions."""
    from repro.env.jaxsim import (compile_trace_dual,
                                  replay_trace_edgesim_static_daso,
                                  run_trace_arrays_static_daso)
    theta, cfg = _daso()
    tr = compile_trace_dual(lam=5.0, seed=1, n_intervals=6, substeps=4)
    fractions = {}
    for pol in ("layer+gobi", "semantic+gobi", "random+daso"):
        ref = replay_trace_edgesim_static_daso(tr, pol, daso_theta=theta,
                                               daso_cfg=cfg)
        jx = run_trace_arrays_static_daso(tr, pol, daso_theta=theta,
                                          daso_cfg=cfg)
        assert ref["tasks_completed"] > 0, pol
        assert_summaries_close(ref, jx)
        fractions[pol] = ref["layer_fraction"]
    assert fractions["layer+gobi"] == 1.0      # fixed-arm deciders decide
    assert fractions["semantic+gobi"] == 0.0
    assert 0.0 <= fractions["random+daso"] <= 1.0


def test_experiments_static_daso_backend_jax():
    """`run_grid_batched`/`run_trace(backend='jax')` route the
    STATIC_DASO_ARMS names through the in-kernel engine; missing
    surrogate products are rejected."""
    import pytest

    from repro.launch.experiments import run_grid_batched, run_trace
    theta, cfg = _daso()
    recs = run_grid_batched("semantic+gobi", seeds=(1,), lams=(5.0,),
                            n_intervals=6, substeps=4, daso_theta=theta,
                            daso_cfg=cfg)
    r1 = run_trace("semantic+gobi", n_intervals=6, lam=5.0, seed=1,
                   substeps=4, backend="jax", daso_theta=theta,
                   daso_cfg=cfg)
    assert np.isclose(r1["reward"], recs[0]["reward"], rtol=1e-12)
    assert recs[0]["policy"] == "semantic+gobi"
    with pytest.raises(ValueError):
        run_grid_batched("random+daso", seeds=(1,), lams=(5.0,),
                         n_intervals=6, substeps=4)
