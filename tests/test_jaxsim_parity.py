"""Jitted fixed-capacity simulator ≙ host EdgeSim, allclose on metrics.

The SoA↔legacy contract is bit-exact (``test_soa_equivalence``); the
jitted backend relaxes it to ``allclose(rtol=1e-4)`` on per-trace summary
metrics — reduction orders differ between its censuses and the host's
sequential ``bincount`` accumulation, but every elementwise float64
physics op matches.  Both sides consume the same compiled trace
(``repro.env.jaxsim.arrays.compile_trace``), replayed through the real
``EdgeSim`` by ``reference.replay_trace_edgesim``.
"""
import numpy as np
import pytest

from repro.env.cluster import make_cluster
from repro.env.jaxsim import (compile_trace, make_static_decider,
                              replay_trace_edgesim, run_grid_arrays,
                              run_trace_arrays)

RTOL, ATOL = 1e-4, 1e-9


def assert_summaries_close(ref, jx, rtol=RTOL, atol=ATOL):
    assert set(ref) == set(jx)
    for k in ref:
        assert np.isclose(ref[k], jx[k], rtol=rtol, atol=atol), \
            f"{k}: host={ref[k]!r} jax={jx[k]!r}"


@pytest.mark.parametrize("lam", [4.0, 9.0])
def test_bestfit_trace_parity_two_lams(lam):
    """20-interval mixed-decision BestFit trace at two arrival rates."""
    dec = make_static_decider("bestfit-rr")
    tr = compile_trace(dec, lam=lam, seed=0, n_intervals=20, substeps=10)
    ref = replay_trace_edgesim(tr)
    jx = run_trace_arrays(tr)
    assert ref["tasks_completed"] > 0
    assert jx["dropped_tasks"] == 0
    assert_summaries_close(ref, jx)


def test_parity_under_ram_pressure():
    """Squeezed RAM exercises the repair fallback, placement failure
    (waiting tasks) and swap-slowdown paths on both backends."""
    cl = make_cluster(ram_scale=0.35)
    dec = make_static_decider("mc")
    tr = compile_trace(dec, lam=14.0, seed=2, n_intervals=12, substeps=8,
                       cluster=cl)
    ref = replay_trace_edgesim(tr, cluster=cl)
    jx = run_trace_arrays(tr, cluster=cl)
    assert ref["wait_intervals"] > 0        # repair actually failed tasks
    assert_summaries_close(ref, jx)


def test_layer_chain_parity():
    """Pure layer-split load: stage precedence + activation transfers."""
    dec = make_static_decider("bestfit-layer")
    tr = compile_trace(dec, lam=8.0, seed=3, n_intervals=15, substeps=10)
    ref = replay_trace_edgesim(tr)
    jx = run_trace_arrays(tr)
    assert ref["layer_fraction"] == 1.0
    assert_summaries_close(ref, jx)


def test_vmap_grid_rows_match_solo_runs():
    """Batched grid row i must equal the solo run of trace i (vmap and
    chunked-thread dispatch change nothing numerically)."""
    dec = make_static_decider("bestfit-rr")
    traces = [compile_trace(dec, lam=lam, seed=s, n_intervals=10, substeps=6)
              for lam in (4.0, 8.0) for s in (0, 1)]
    grid = run_grid_arrays(traces, threads=2)
    for i, tr in enumerate(traces):
        solo = run_trace_arrays(tr)
        for k in solo:
            assert np.isclose(solo[k], grid[i][k], rtol=1e-12, atol=1e-12), \
                f"row {i} {k}: solo={solo[k]!r} grid={grid[i][k]!r}"


def test_capacity_overflow_is_counted_not_silent():
    """Arrivals beyond ``max_active`` must surface in ``dropped_tasks``."""
    dec = make_static_decider("mc")
    tr = compile_trace(dec, lam=10.0, seed=0, n_intervals=8, substeps=4)
    jx = run_trace_arrays(tr, max_active=8)
    assert jx["dropped_tasks"] > 0


def test_experiments_backend_jax_matches_batched():
    """`run_trace(backend='jax')` and `run_grid(backend='jax')` route
    through the same kernels and agree with run_grid_batched."""
    from repro.launch.experiments import run_grid, run_grid_batched, run_trace
    r1 = run_trace("mc", n_intervals=6, lam=4.0, seed=1, substeps=5,
                   backend="jax")
    recs = run_grid_batched("mc", seeds=(1,), lams=(4.0,), n_intervals=6,
                            substeps=5)
    assert np.isclose(r1["reward"], recs[0]["reward"], rtol=1e-12)
    grid = run_grid(("mc",), seeds=(1,), lams=(4.0,), n_intervals=6,
                    substeps=5, backend="jax")
    assert grid[0]["seed"] == 1 and grid[0]["lam"] == 4.0
    assert np.isclose(grid[0]["reward"], r1["reward"], rtol=1e-12)
