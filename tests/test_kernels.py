"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, moe_route, rglru_scan, selective_scan
from repro.kernels import ref

rng = np.random.RandomState(42)


@pytest.mark.parametrize("b,sq,sk,h,kvh,hd", [
    (2, 64, 64, 4, 2, 32),
    (1, 128, 128, 8, 8, 64),
    (2, 96, 96, 4, 1, 32),        # GQA kv=1 (recurrentgemma-style)
    (1, 33, 77, 2, 2, 16),        # ragged, non-multiple sizes
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, sq, sk, h, kvh, hd, dtype):
    q = jnp.asarray(rng.randn(b, sq, h, hd), dtype)
    k = jnp.asarray(rng.randn(b, sk, kvh, hd), dtype)
    v = jnp.asarray(rng.randn(b, sk, kvh, hd), dtype)
    out = flash_attention(q, k, v, causal=True, q_block=32, kv_block=32)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window", [8, 32])
def test_flash_attention_sliding_window(window):
    b, s, h, kvh, hd = 2, 80, 4, 2, 32
    q = jnp.asarray(rng.randn(b, s, h, hd), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, kvh, hd), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, kvh, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          q_block=16, kv_block=16)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_attention_noncausal():
    q = jnp.asarray(rng.randn(1, 40, 2, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 56, 2, 64), jnp.float32)
    v = jnp.asarray(rng.randn(1, 56, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=False, q_block=16, kv_block=16)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("b,s,d,n,chunk", [
    (2, 37, 16, 4, 16), (1, 128, 64, 16, 32), (3, 15, 8, 2, 8),
])
def test_selective_scan(b, s, d, n, chunk):
    dA = jnp.asarray(rng.uniform(0.5, 1.0, (b, s, d, n)), jnp.float32)
    dBx = jnp.asarray(rng.randn(b, s, d, n) * 0.1, jnp.float32)
    C = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    out = selective_scan(dA, dBx, C, chunk=chunk, d_block=8)
    want = ref.selective_scan_ref(dA, dBx, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,s,w,chunk", [(2, 37, 24, 16), (1, 64, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan(b, s, w, chunk, dtype):
    a = jnp.asarray(rng.uniform(0.8, 1.0, (b, s, w)), dtype)
    bx = jnp.asarray(rng.randn(b, s, w) * 0.1, dtype)
    out = rglru_scan(a, bx, chunk=chunk, w_block=32)
    want = ref.rglru_scan_ref(a, bx)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=tol)


@pytest.mark.parametrize("S,E,k,block", [
    (64, 8, 2, 32), (100, 16, 4, 32), (33, 4, 1, 16),
])
def test_moe_route(S, E, k, block):
    logits = jnp.asarray(rng.randn(S, E), jnp.float32)
    eid, gate, slot = moe_route(logits, k, block=block)
    eid2, gate2, slot2 = ref.moe_route_ref(logits, k)
    assert (np.asarray(eid) == np.asarray(eid2)).all()
    assert (np.asarray(slot) == np.asarray(slot2)).all()
    np.testing.assert_allclose(np.asarray(gate), np.asarray(gate2), atol=1e-5)


def test_moe_route_slots_are_dense_per_expert():
    logits = jnp.asarray(rng.randn(200, 8), jnp.float32)
    eid, _, slot = moe_route(logits, 2, block=64)
    eid, slot = np.asarray(eid).ravel(), np.asarray(slot).ravel()
    for e in range(8):
        s = np.sort(slot[eid == e])
        assert (s == np.arange(len(s))).all()   # 0..n_e-1 exactly once
