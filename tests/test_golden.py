"""Golden-trace regression fixtures for the jitted backend.

The EdgeSim-replay parity suite catches kernel↔host divergence but
would silently drift if BOTH backends moved together (a JAX/XLA upgrade
changing shared pure-function numerics, an accidental physics edit that
mirrors itself into the replay).  These tests pin the jitted backend's
summary metrics — and the train-mode finetuned-theta fingerprint —
against committed JSON fixtures at a tolerance (`tools/regen_golden.py`
regenerates them when a change is *intentional*).
"""
import importlib.util
import json
import os

import numpy as np
import pytest

_HERE = os.path.dirname(os.path.abspath(__file__))
_TOOL = os.path.join(os.path.dirname(_HERE), "tools", "regen_golden.py")
_spec = importlib.util.spec_from_file_location("regen_golden", _TOOL)
regen_golden = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(regen_golden)

_MSG = ("golden fixture drift — if this change intentionally moves the "
        "numbers, regenerate with: PYTHONPATH=src python "
        "tools/regen_golden.py")


def _load(fname):
    path = os.path.join(_HERE, "data", fname)
    assert os.path.exists(path), f"missing fixture {path} — run {_TOOL}"
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("fname", sorted(regen_golden.CASES))
def test_golden_fixture(fname):
    golden = _load(fname)
    fresh = regen_golden.CASES[fname]()
    assert golden["case"] == fresh["case"], _MSG
    assert set(golden["summary"]) == set(fresh["summary"]), _MSG
    for k, v in golden["summary"].items():
        assert np.isclose(fresh["summary"][k], v,
                          rtol=regen_golden.RTOL,
                          atol=regen_golden.ATOL), \
            f"{fname}: {k}: fixture={v!r} fresh={fresh['summary'][k]!r}; " \
            + _MSG
    # extra array payloads (theta_fingerprint, gillis_q, ...) compare
    # generically, so new fixtures only need a compute_* entry; the
    # key-set check catches a compute_* gaining a payload the committed
    # fixture doesn't pin yet
    assert set(golden) == set(fresh), _MSG
    for key in set(golden) - {"case", "summary"}:
        np.testing.assert_allclose(
            np.asarray(fresh[key]), np.asarray(golden[key]),
            rtol=regen_golden.RTOL, atol=regen_golden.ATOL,
            err_msg=f"{fname}: {key}; " + _MSG)
