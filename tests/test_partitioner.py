"""Gillis DP partitioner: optimality and feasibility properties."""
import itertools

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config
from repro.core.partitioner import (LayerCost, memory_feasible_partition,
                                    model_layer_costs, optimal_partition,
                                    pipeline_latency)


def brute_force(costs, max_k, speed, hop_bw):
    L = len(costs)
    best = (None, float("inf"))
    for k in range(1, min(max_k, L) + 1):
        for mids in itertools.combinations(range(1, L), k - 1):
            cuts = [0] + list(mids) + [L]
            lat = pipeline_latency(costs, cuts, speed, hop_bw)
            if lat < best[1]:
                best = (cuts, lat)
    return best


def test_dp_matches_brute_force_single_speed():
    rng = np.random.RandomState(0)
    costs = [LayerCost(float(rng.uniform(1, 10)), float(rng.uniform(0.1, 2)),
                       1.0) for _ in range(7)]
    cuts, lat = optimal_partition(costs, 4, [1.0], hop_bw=1.0)
    bcuts, blat = brute_force(costs, 4, 1.0, 1.0)
    assert lat <= blat + 1e-9


def test_more_fragments_never_help_without_speedup():
    """With one speed, hops only add cost -> optimum is one fragment."""
    costs = [LayerCost(5.0, 3.0, 1.0)] * 6
    cuts, lat = optimal_partition(costs, 6, [1.0], hop_bw=1.0)
    assert len(cuts) == 2                      # [0, L]
    assert lat == pytest.approx(30.0)


def test_single_request_latency_prefers_one_fast_fragment():
    """For one request, the latency optimum is the whole chain on the
    fastest worker (cuts exist for memory/throughput, not latency)."""
    costs = [LayerCost(10.0, 0.01, 1.0)] * 4
    cuts, lat = optimal_partition(costs, 4, [1.0, 100.0], hop_bw=1e9)
    assert len(cuts) == 2
    assert lat == pytest.approx(40.0 / 100.0)


def test_exact_fragments_count_and_latency():
    """Forcing K fragments with equal speeds: K segments, latency =
    total work + K-1 hops (any tie-broken cut placement is optimal)."""
    costs = [LayerCost(5.0, 2.0, 1.0)] * 8
    cuts, lat = optimal_partition(costs, 4, [1.0], hop_bw=1.0, exact=True)
    sizes = [b - a for a, b in zip(cuts[:-1], cuts[1:])]
    assert len(sizes) == 4 and all(sz >= 1 for sz in sizes)
    assert lat == pytest.approx(8 * 5.0 + 3 * 2.0)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 10), st.integers(1, 5), st.integers(0, 10**6))
def test_dp_cuts_are_valid_partitions(L, K, seed):
    rng = np.random.RandomState(seed)
    costs = [LayerCost(float(rng.uniform(1, 10)),
                       float(rng.uniform(0.1, 2)), 1.0) for _ in range(L)]
    cuts, lat = optimal_partition(costs, K, [1.0, 2.0], hop_bw=1.0)
    assert cuts[0] == 0 and cuts[-1] == L
    assert all(a < b for a, b in zip(cuts[:-1], cuts[1:]))
    assert np.isfinite(lat) and lat > 0


def test_memory_feasible_partition_respects_budget():
    costs = [LayerCost(1.0, 1.0, float(p)) for p in [3, 3, 3, 3, 3, 3]]
    cuts = memory_feasible_partition(costs, ram_budget_bytes=7.0)
    for a, b in zip(cuts[:-1], cuts[1:]):
        assert sum(c.param_bytes for c in costs[a:b]) <= 7.0
    with pytest.raises(ValueError):
        memory_feasible_partition(costs, ram_budget_bytes=2.0)


def test_model_layer_costs_all_archs():
    for arch in ("tinyllama-1.1b", "qwen2-moe-a2.7b", "falcon-mamba-7b",
                 "recurrentgemma-9b", "musicgen-medium"):
        cfg = get_config(arch)
        costs = model_layer_costs(cfg, seq=2048, batch=1)
        assert len(costs) == cfg.num_layers
        assert all(c.flops > 0 and c.param_bytes > 0 for c in costs)
        # partition the real cost table
        cuts, lat = optimal_partition(costs, 4, [197e12, 197e12], 50e9)
        assert cuts[-1] == cfg.num_layers
