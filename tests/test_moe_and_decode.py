"""Deeper MoE + decode-path coverage."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, forward, init_cache, init_params
from repro.models import moe as M

rng = np.random.RandomState(3)


def _moe_cfg(gs=8, dispatch="onehot", cf=4.0, experts=4, k=2, shared=0):
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, group_size=gs, dispatch=dispatch, capacity_factor=cf,
        num_experts=experts, top_k=k,
        num_shared_experts=shared, shared_d_ff=cfg.d_model if shared else 0))


@pytest.mark.parametrize("gs", [4, 8, 64])
@pytest.mark.parametrize("shared", [0, 1])
def test_moe_gather_matches_onehot(gs, shared):
    cfg = _moe_cfg(gs=gs, shared=shared)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.randn(2, 11, cfg.d_model) * 0.5, jnp.float32)
    a = M.moe_apply(p, x, cfg)
    b = M.moe_apply(
        p, x, dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, dispatch="gather")))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_moe_matches_dense_reference_when_lossless():
    """With capacity >> needed, MoE equals the per-token dense expert mix."""
    cfg = _moe_cfg(gs=16, cf=8.0)
    m = cfg.moe
    p = M.moe_init(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jnp.asarray(rng.randn(1, 16, cfg.d_model) * 0.5, jnp.float32)
    x2 = x.reshape(-1, cfg.d_model)
    tv, ti, _ = M.router_topk(p, x2, m)
    act = jax.nn.silu
    want = []
    for t in range(x2.shape[0]):
        y = 0
        for j in range(m.top_k):
            e = int(ti[t, j])
            h = act(x2[t] @ p["w_gate"][e]) * (x2[t] @ p["w_up"][e])
            y = y + tv[t, j] * (h @ p["w_down"][e])
        want.append(y)
    want = jnp.stack(want).reshape(x.shape)
    got = M.moe_apply(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 a hot expert drops tokens; output stays finite and
    dropped tokens contribute only their shared/zero path."""
    cfg = _moe_cfg(gs=16, cf=1.0)
    p = M.moe_init(jax.random.PRNGKey(2), cfg, jnp.float32)
    # identical tokens -> all route to the same experts -> guaranteed drops
    x = jnp.ones((1, 16, cfg.d_model), jnp.float32) * 0.3
    y = M.moe_apply(p, x, cfg)
    assert bool(jnp.isfinite(y).all())
    yg = M.moe_apply(p, x, dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="gather")))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yg), atol=1e-5)


def test_aux_loss_uniform_router_is_one():
    """Perfectly balanced routing gives aux loss ~= 1 (Switch normalization)."""
    cfg = _moe_cfg(experts=4, k=1)
    p = M.moe_init(jax.random.PRNGKey(3), cfg, jnp.float32)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])      # uniform probs
    x = jnp.asarray(rng.randn(4, 16, cfg.d_model), jnp.float32)
    aux = M.aux_load_balance_loss(p, x, cfg)
    assert 0.9 < float(aux) < 1.1


# ------------------------------------------------------- decode paths

def test_sliding_window_decode_ring_wraps():
    """Decoding past the window: positions beyond W reuse ring slots and
    logits stay finite; early positions no longer influence output."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, W = 1, 8
    cache = init_cache(cfg, b, ctx_len=64, sliding=W)
    assert cache["body"]["b0"]["k"].shape[2] == W or \
        cache["body"]["b0"]["k"].shape[1] == W
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, 1)), jnp.int32)
    logits = None
    for pos in range(2 * W):
        logits, cache = decode_step(params, tok, cache, jnp.int32(pos), cfg)
    assert bool(jnp.isfinite(logits).all())


def test_long_context_decode_ssm_state_only():
    """SSM decode cache is O(1) in context length."""
    cfg = get_config("falcon-mamba-7b").reduced()
    c1 = init_cache(cfg, 2, ctx_len=128)
    c2 = init_cache(cfg, 2, ctx_len=1 << 19)
    s1 = sum(np.prod(x.shape) for x in jax.tree.leaves(c1))
    s2 = sum(np.prod(x.shape) for x in jax.tree.leaves(c2))
    assert s1 == s2


def test_decode_batch_invariance():
    """Per-row decode results must not depend on other rows in the batch."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (3, 1)), jnp.int32)
    cache3 = init_cache(cfg, 3, ctx_len=16)
    l3, _ = decode_step(params, toks, cache3, jnp.int32(0), cfg)
    cache1 = init_cache(cfg, 1, ctx_len=16)
    l1, _ = decode_step(params, toks[1:2], cache1, jnp.int32(0), cfg)
    np.testing.assert_allclose(np.asarray(l3[1]), np.asarray(l1[0]),
                               atol=1e-5)


def test_mrope_vs_rope_differ_only_with_2d_positions():
    """With purely textual (t==h==w) positions M-RoPE == RoPE sections."""
    from repro.models.layers import apply_mrope, apply_rope
    x = jnp.asarray(rng.randn(1, 6, 2, 16), jnp.float32)
    pos = jnp.arange(6, dtype=jnp.int32)[None]
    p3 = jnp.broadcast_to(pos[:, None, :], (1, 3, 6))
    a = apply_mrope(x, p3, (2, 3, 3), theta=100.0)
    b = apply_rope(x, pos, theta=100.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # spatial positions diverge
    p3b = p3.at[:, 1].add(5)
    c = apply_mrope(x, p3b, (2, 3, 3), theta=100.0)
    assert float(jnp.abs(c - a).max()) > 1e-3
