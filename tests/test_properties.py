"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import mab
from repro.kernels import ref
from repro.models.layers import apply_rope, causal_conv1d, rmsnorm

S = settings(max_examples=25, deadline=None)


@S
@given(st.integers(1, 3), st.integers(2, 24), st.integers(2, 32),
       st.integers(0, 2**31 - 1))
def test_rmsnorm_scale_invariant_direction(b, s, d, seed):
    """rmsnorm(cx) == rmsnorm(x) for c>0 — exact with eps=0 (with eps>0
    the invariance intentionally breaks when ||x||^2 ~ eps, which
    hypothesis duly discovered)."""
    rng = np.random.RandomState(seed % 2**31)
    x = jnp.asarray(rng.randn(b, s, d), jnp.float32) + 0.1
    w = jnp.asarray(rng.randn(d) * 0.1, jnp.float32)
    a = rmsnorm(x, w, eps=0.0)
    bb = rmsnorm(3.7 * x, w, eps=0.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                               rtol=2e-4, atol=2e-5)


@S
@given(st.integers(2, 40), st.integers(2, 8), st.integers(0, 10**6))
def test_rope_preserves_norm(s, h, seed):
    """Rotations preserve per-head vector norms."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(1, s, h, 32), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    y = apply_rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-4)


@S
@given(st.integers(1, 2), st.integers(4, 32), st.integers(1, 8),
       st.integers(0, 10**6))
def test_causal_conv_is_causal(b, s, d, seed):
    """Changing the future must not change the past."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(b, s, d), jnp.float32)
    w = jnp.asarray(rng.randn(4, d), jnp.float32)
    bias = jnp.zeros(d)
    y0 = causal_conv1d(x, w, bias)
    t = s // 2
    x2 = x.at[:, t:].set(999.0)
    y1 = causal_conv1d(x2, w, bias)
    np.testing.assert_array_equal(np.asarray(y0[:, :t]),
                                  np.asarray(y1[:, :t]))


@S
@given(st.integers(2, 6), st.integers(8, 64), st.integers(0, 10**6))
def test_attention_rows_are_convex_weights(h, s, seed):
    """Attention output lies in the convex hull of V rows: for constant V
    the output equals that constant."""
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(1, s, h, 16), jnp.float32)
    k = jnp.asarray(rng.randn(1, s, h, 16), jnp.float32)
    v = jnp.ones((1, s, h, 16), jnp.float32) * 2.5
    out = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), 2.5, rtol=1e-5)


@S
@given(st.integers(1, 60), st.integers(0, 10**6))
def test_selective_scan_zero_input_decays(s, seed):
    """With dBx=0 and dA in (0,1), the state stays zero -> y == 0."""
    rng = np.random.RandomState(seed)
    dA = jnp.asarray(rng.uniform(0.1, 0.99, (1, s, 4, 3)), jnp.float32)
    dBx = jnp.zeros((1, s, 4, 3))
    C = jnp.asarray(rng.randn(1, s, 3), jnp.float32)
    y = ref.selective_scan_ref(dA, dBx, C)
    np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-7)


@S
@given(st.lists(st.floats(0.0, 1.0), min_size=1, max_size=20),
       st.lists(st.floats(0.0, 1000.0), min_size=1, max_size=20),
       st.integers(0, 10**6))
def test_mab_q_estimates_stay_in_unit_interval(accs, resps, seed):
    """Rewards are convex combos of {0,1} and accuracy -> Q in [0,1]."""
    n = min(len(accs), len(resps))
    s = mab.init_state(1)
    apps = jnp.zeros(n, jnp.int32)
    sla = jnp.full((n,), 100.0)
    resp = jnp.asarray(resps[:n], jnp.float32)
    acc = jnp.asarray(accs[:n], jnp.float32)
    rng = np.random.RandomState(seed)
    dec = jnp.asarray(rng.randint(0, 2, n), jnp.int32)
    for _ in range(3):
        s = mab.end_of_interval(s, apps, sla, resp, acc, dec)
    assert (np.asarray(s.Q) >= 0).all() and (np.asarray(s.Q) <= 1).all()
    assert float(s.eps) <= 1.0 and float(s.eps) >= 0.0


@S
@given(st.integers(8, 64), st.integers(2, 8), st.integers(1, 4),
       st.integers(0, 10**6))
def test_moe_route_slot_invariants(S_, E, k, seed):
    """Every kept slot id is unique per expert and < count of that expert."""
    k = min(k, E)
    rng = np.random.RandomState(seed)
    logits = jnp.asarray(rng.randn(S_, E), jnp.float32)
    eid, gate, slot = ref.moe_route_ref(logits, k)
    eid, slot = np.asarray(eid), np.asarray(slot)
    gate = np.asarray(gate)
    np.testing.assert_allclose(gate.sum(-1), 1.0, rtol=1e-4)
    for e in range(E):
        ss = np.sort(slot[eid == e])
        assert (ss == np.arange(len(ss))).all()


@S
@given(st.integers(0, 10**6))
def test_checkpoint_roundtrip(seed):
    from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint
    import tempfile
    rng = np.random.RandomState(seed)
    tree = {"a": rng.randn(3, 4).astype(np.float32),
            "b": [rng.randn(2).astype(np.float16),
                  {"c": np.int32(rng.randint(100))}]}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=7)
        got, step = restore_checkpoint(d, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
