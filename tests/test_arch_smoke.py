"""Per-architecture smoke tests: reduced (2-layer, d<=512, <=4 experts)
variants of every assigned architecture run one forward + one train step on
CPU; output shapes and finiteness asserted.  Also checks analytic parameter
counts against the assignment targets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.steps import make_train_step
from repro.models import decode_step, forward, init_params, prefill
from repro.optim.optimizers import make_optimizer

PARAM_TARGETS_B = {
    "qwen1.5-110b": (100, 120), "recurrentgemma-9b": (8, 12),
    "musicgen-medium": (1.2, 2.2), "qwen2-moe-a2.7b": (12, 16),
    "tinyllama-1.1b": (1.0, 1.25), "nemotron-4-340b": (325, 355),
    "falcon-mamba-7b": (6.5, 8.0), "qwen2-vl-7b": (7.0, 8.3),
    "kimi-k2-1t-a32b": (950, 1100), "llama3-405b": (390, 420),
}
ACTIVE_TARGETS_B = {"qwen2-moe-a2.7b": (2.0, 3.4), "kimi-k2-1t-a32b": (28, 38)}


def make_batch(cfg, b=2, s=16, seed=0, labels=True):
    rng = np.random.RandomState(seed)
    shape = (b, s) if not cfg.num_codebooks else (b, s, cfg.num_codebooks)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, shape),
                                   jnp.int32)}
    if labels:
        batch["labels"] = batch["tokens"]
    if cfg.visual_frontend:
        batch["visual_embeds"] = jnp.asarray(
            rng.randn(b, s, cfg.d_model) * 0.1, jnp.float32)
        batch["visual_mask"] = jnp.zeros((b, s), bool).at[:, 2:5].set(True)
    if cfg.cross_attention:
        batch["cond"] = jnp.asarray(
            rng.randn(b, cfg.cond_len, cfg.d_model) * 0.1, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, aux = forward(params, batch, cfg)
    want = (2, 16, cfg.num_codebooks, cfg.vocab_size) if cfg.num_codebooks \
        else (2, 16, cfg.vocab_size)
    assert logits.shape == want
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    init_opt, _ = make_optimizer(cfg.optimizer)
    opt_state = init_opt(params)
    step = make_train_step(cfg, mesh=None, lr=1e-3)
    batch = make_batch(cfg)
    params2, opt_state2, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    batch = make_batch(cfg, b=b, s=s + 1, labels=False)
    logits_full, _ = forward(params, batch, cfg)
    pre = {k: (v[:, :s] if k != "cond" else v) for k, v in batch.items()}
    _, cache = prefill(params, pre, cfg)
    extras = {}
    if cfg.cross_attention:
        extras["cond"] = batch["cond"]
    if cfg.visual_frontend:
        extras = {"visual_embeds": batch["visual_embeds"][:, s:s + 1],
                  "visual_mask": batch["visual_mask"][:, s:s + 1]}
    ld, _ = decode_step(params, batch["tokens"][:, s:s + 1], cache,
                        jnp.int32(s), cfg, batch_extras=extras or None)
    err = float(jnp.abs(ld[:, 0] - logits_full[:, s]).max())
    assert err < 2e-3, f"decode mismatch {err}"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_param_count(arch):
    cfg = get_config(arch)
    lo, hi = PARAM_TARGETS_B[arch]
    n = cfg.param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.1f}B outside [{lo},{hi}]"
    if arch in ACTIVE_TARGETS_B:
        lo, hi = ACTIVE_TARGETS_B[arch]
        a = cfg.active_param_count() / 1e9
        assert lo <= a <= hi, f"{arch} active: {a:.1f}B outside [{lo},{hi}]"


def test_layer_kinds_cover_patterns():
    cfg = get_config("recurrentgemma-9b")
    kinds = cfg.layer_kinds
    assert len(kinds) == 38
    assert kinds[:3] == ("rglru", "rglru", "local_attn")
    assert kinds.count("local_attn") == 12          # 12 full periods
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.layer_kinds[0] == "attn"            # first_k_dense
    assert set(kimi.layer_kinds[1:]) == {"attn_moe"}
