"""End-to-end training driver: a ~100M-param tinyllama-family model
trained for a few hundred steps on the deterministic token pipeline;
checkpoints and verifies the loss actually decreases.

Run:  PYTHONPATH=src python examples/train_tinyllama.py [--steps 300]
"""
import argparse
import sys

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
args = ap.parse_args()

losses = train_main([
    "--arch", "tinyllama-1.1b", "--reduced",
    "--d-model", "512", "--layers", "8",
    "--steps", str(args.steps), "--batch", "8", "--seq", "256",
    "--lr", "2e-3",
    "--ckpt", "/tmp/repro_tinyllama_ckpt",
])
import numpy as np
assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss did not improve"
print("training example OK")
