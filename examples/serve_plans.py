"""Serving example: SLA-aware plan selection (the paper's split decision
as a TPU serving-plan choice) over a stream of tight/loose deadline
requests.

Run:  PYTHONPATH=src python examples/serve_plans.py
"""
from repro.launch.serve import main

main(["--requests", "12"])
