"""End-to-end reproduction of the paper's main experiment (Table 4 shape):
pretrain the MAB with feedback-based eps-greedy, then compare SplitPlace
against ablations and baselines on the 50-worker mobile-edge testbed.

Runs through the canonical interval loop in ``repro.launch.experiments``
(the same ``pretrain``/``run_grid`` pipeline the Table 4 and sensitivity
benchmarks use), so examples and benchmarks share one code path.

Run:  PYTHONPATH=src python examples/edge_experiment.py [--full]
"""
import argparse

from repro.launch.experiments import pretrain, run_grid

POLICIES = ["splitplace", "mab+gobi", "semantic+gobi", "layer+gobi",
            "random+daso", "gillis", "mc"]

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true", help="paper-scale run")
args = ap.parse_args()
pre_n, n, sub = (200, 100, 30) if args.full else (60, 25, 6)

print(f"pretraining MAB for {pre_n} intervals ...")
pre = pretrain(pre_n, lam=6.0, seed=7, substeps=sub, policies=POLICIES)
print(f"R estimates (s): {pre.mab_state.R}")
print(f"Q estimates:\n{pre.mab_state.Q}")

records = run_grid(POLICIES, seeds=(0,), lams=(6.0,), n_intervals=n,
                   substeps=sub, mab_state=pre.mab_state,
                   gillis_policy=pre.gillis_policy)
for r in records:
    print(f"{r['policy']:15s} reward={r['reward']:.4f} "
          f"viol={r['sla_violations']:.2f} acc={r['accuracy']:.4f} "
          f"resp={r['response_intervals']:.2f} "
          f"energy={r['energy_mwhr']:.4f}MWhr fair={r['fairness']:.2f}")
