"""End-to-end reproduction of the paper's main experiment (Table 4 shape):
pretrain the MAB with feedback-based eps-greedy, then compare SplitPlace
against ablations and baselines on the 50-worker mobile-edge testbed.

Run:  PYTHONPATH=src python examples/edge_experiment.py [--full]
"""
import argparse

from repro.core.splitplace import pretrain_mab, run_experiment

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true", help="paper-scale run")
args = ap.parse_args()
pre_n, n, sub = (200, 100, 30) if args.full else (60, 25, 6)

print(f"pretraining MAB for {pre_n} intervals ...")
state, _ = pretrain_mab(n_intervals=pre_n, substeps=sub, seed=7)
print(f"R estimates (s): {state.R}")
print(f"Q estimates:\n{state.Q}")

for pol in ["splitplace", "mab+gobi", "semantic+gobi", "layer+gobi",
            "random+daso", "gillis", "mc"]:
    ms = state if pol in ("splitplace", "mab+gobi") else None
    r = run_experiment(pol, n_intervals=n, lam=6.0, seed=0, mab_state=ms,
                       substeps=sub)
    print(f"{pol:15s} reward={r['reward']:.4f} "
          f"viol={r['sla_violations']:.2f} acc={r['accuracy']:.4f} "
          f"resp={r['response_intervals']:.2f} "
          f"energy={r['energy_mwhr']:.4f}MWhr fair={r['fairness']:.2f}")
