"""The paper's layer-wise split as a REAL SPMD pipeline: shard_map over a
4-device 'stage' mesh, ppermute activation forwarding, GPipe microbatch
schedule — and a check that it matches the monolithic forward exactly.

Run:  PYTHONPATH=src python examples/pipeline_spmd.py
(sets the forced device count itself; run in a fresh interpreter)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import forward, init_params
from repro.serving.pipeline_smap import pipeline_shard_map

cfg = get_config("tinyllama-1.1b").reduced(max_layers=4)
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 16)),
                               jnp.int32)}
want, _ = forward(params, batch, cfg)

mesh = jax.make_mesh((4,), ("stage",))
print(f"mesh: {mesh.shape} — one layer-split fragment per stage device")
for M in (4, 8):
    got = pipeline_shard_map(params, batch, cfg, mesh, num_microbatches=M)
    err = float(jnp.abs(got - want).max())
    print(f"microbatches={M}: pipeline vs monolithic max err = {err:.2e}")
    assert err < 2e-4
print("SPMD pipeline OK")
