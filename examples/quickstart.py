"""Quickstart: the three layers of the framework in one script.

1. SplitPlace policy on the mobile-edge simulator (the paper's system);
2. a reduced assigned-architecture model doing a real train step;
3. the MAB-driven serving engine choosing execution plans by deadline.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# ---- 1. the paper's scheduler on the edge simulator ------------------
from repro.core.splitplace import run_experiment

r = run_experiment("splitplace", n_intervals=10, lam=4.0, seed=0,
                   train=True, substeps=6)
print(f"[edge sim] reward={r['reward']:.3f} "
      f"violations={r['sla_violations']:.2f} accuracy={r['accuracy']:.3f}")

# ---- 2. one real train step of an assigned architecture --------------
from repro.configs import get_config
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim.optimizers import make_optimizer

cfg = get_config("qwen2-moe-a2.7b").reduced()
params = init_params(jax.random.PRNGKey(0), cfg)
init_opt, _ = make_optimizer(cfg.optimizer)
opt_state = init_opt(params)
step = jax.jit(make_train_step(cfg, mesh=None, lr=1e-3))
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 32))),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 32)))}
params, opt_state, m = step(params, opt_state, batch)
print(f"[moe train] loss={float(m['loss']):.3f} "
      f"grad_norm={float(m['grad_norm']):.2f}")

# ---- 3. TPU-native SplitPlace: plan selection by deadline ------------
from repro.serving.engine import Request, SplitPlaceEngine

cfg_s = get_config("tinyllama-1.1b").reduced(max_d_model=128, max_layers=2)
params_s = init_params(jax.random.PRNGKey(1), cfg_s)
eng = SplitPlaceEngine(params_s, cfg_s)
tok = rng.randint(0, cfg_s.vocab_size, (1, 32)).astype(np.int32)
eng.warmup(tok)
res = eng.serve(Request(tokens=tok, deadline_s=10.0))
print(f"[serving] plan={'layer' if res.plan == 0 else 'semantic'} "
      f"latency={res.latency_s*1e3:.1f}ms fidelity={res.fidelity:.3f}")
print("quickstart OK")
