"""TPU-native SplitPlace: MAB plan selection over real executions.

Measures the layer-pipeline vs semantic-branch latency/fidelity trade-off
on a reduced model and shows the engine's UCB converging to
deadline-appropriate plans (DESIGN.md §2.2)."""
from __future__ import annotations

import argparse
import json
import os

try:
    from benchmarks._provenance import provenance
except ImportError:       # run as a loose script from benchmarks/
    from _provenance import provenance

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Request, SplitPlaceEngine
from repro.serving.plans import LAYER_PLAN


def run(n_requests=40, seed=0, out_json=None):
    cfg = get_config("tinyllama-1.1b").reduced(max_d_model=512, max_layers=6)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = SplitPlaceEngine(params, cfg, num_stages=2, num_branches=2,
                           seed=seed)
    rng = np.random.RandomState(seed)
    tok = rng.randint(0, cfg.vocab_size, (4, 256)).astype(np.int32)
    eng.warmup(tok)
    # measure the plan latencies once for the report
    _, t_layer = eng._run(0, {"tokens": tok})
    _, t_sem = eng._run(1, {"tokens": tok})
    results = []
    for i in range(n_requests):
        tight = rng.rand() < 0.5
        # headroom covers the engine's slice-queue penalty (steady ~1.5x)
        ddl = (t_sem * 2.5) if tight else (t_layer * 4.0)
        results.append(eng.serve(Request(tokens=tok, deadline_s=float(ddl))))
    tail = results[n_requests // 2:]
    layer_frac_tail = float(np.mean([r.plan == LAYER_PLAN for r in tail]))
    met = float(np.mean([r.met_deadline for r in results]))
    fid_layer = [r.fidelity for r in results if r.plan == LAYER_PLAN]
    fid_sem = [r.fidelity for r in results if r.plan != LAYER_PLAN]
    summary = dict(
        latency_layer_ms=t_layer * 1e3, latency_semantic_ms=t_sem * 1e3,
        speedup=t_layer / max(t_sem, 1e-9),
        deadline_met_frac=met,
        layer_plan_frac_tail=layer_frac_tail,
        fidelity_layer=float(np.mean(fid_layer)) if fid_layer else 1.0,
        fidelity_semantic=float(np.mean(fid_sem)) if fid_sem else 0.0,
        reward=float(np.mean([r.reward for r in results])),
    )
    for k, v in summary.items():
        print(f"{k:24s} {v:.4f}")
    summary["provenance"] = provenance()
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        json.dump(summary, open(out_json, "w"), indent=1)
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/results/serving_plans.json")
    args = ap.parse_args()
    run(out_json=args.out)
