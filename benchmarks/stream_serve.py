"""Streaming serve benchmark: sustained tasks/sec at millions of tasks.

Sole owner of ``benchmarks/results/stream_serve.json`` and
``benchmarks/results/obs/stream_serve.jsonl``.  Two measurements over
the always-on serving loop (``repro.env.jaxsim.stream.serve`` — host
feeder thread double-buffering chunk tapes against donated-carry jitted
chunk executions):

  * **speedup** — warm per-chunk latency with the one-compile-per-
    chunk-shape runner cache vs a naive driver that recompiles every
    chunk (``clear_cache()`` before each call).  The cached path must
    clear ``MIN_SPEEDUP`` (≥3×, the ``jaxsim_learned.py`` convention) —
    in practice the gap is orders of magnitude, which is exactly why a
    streaming driver must never take a per-chunk compile;
  * **soak** — ≥10⁶ tasks through one process, asserting the serving
    loop is genuinely steady-state: flat memory (peak RSS within 10% of
    its value at 25% progress — the feeder/ring/carry all being
    fixed-capacity means nothing accumulates) and flat ring occupancy
    (second-half mean within 5% of first-half), reporting the headline
    ``steady_tasks_per_sec`` (completions over wall time excluding the
    compile-bearing first chunk).

``PYTHONPATH=src python -m benchmarks.stream_serve [--quick] [--tasks N]``

``--quick`` is the CI size (~10⁴ tasks): same assertions minus the
long-horizon RSS flatness (a 10-chunk run never leaves the allocator
warm-up regime, so only the soak path pins memory).
"""
from __future__ import annotations

import argparse
import json
import os
import time

try:
    from benchmarks._provenance import obs_scope as _obs_scope
    from benchmarks._provenance import provenance
except ImportError:       # run as a loose script from benchmarks/
    from _provenance import obs_scope as _obs_scope
    from _provenance import provenance

#: hard floor — warm cached chunk latency vs recompile-every-chunk
MIN_SPEEDUP = 3.0
#: soak acceptance: peak RSS within 10% of the 25%-progress RSS
MAX_RSS_GROWTH = 0.10
#: soak acceptance: second-half mean ring occupancy within 5% of first
MAX_OCCUPANCY_DRIFT = 0.05

SUMMARY_KEYS = ("accuracy", "sla_violations", "reward",
                "response_intervals", "wait_intervals", "energy_mwhr",
                "fairness", "tasks_completed", "dropped_tasks")


def _rss_mb() -> float:
    with open("/proc/self/status") as f:
        for line in f:
            if line.startswith("VmRSS:"):
                return float(line.split()[1]) / 1024.0
    return 0.0


def run_speedup(chunk: int = 8, n_chunks: int = 4, lam: float = 6.0,
                substeps: int = 3) -> dict:
    """Warm cached per-chunk time vs clear_cache()-forced recompile per
    chunk, over identical fixed-size chunk tapes."""
    from repro.env import jaxsim
    from repro.env.jaxsim import stream

    eng, es0, fkw = stream.make_stream_policy("mc")

    def feeder():
        return stream.StreamFeeder(lam=lam, seed=0, interval_s=300.0,
                                   substeps=substeps, **fkw)

    f = feeder()
    tapes = [f.next_chunk(chunk) for _ in range(n_chunks)]

    def runner():
        return stream.StreamRunner(eng, es0, interval_s=300.0,
                                   substeps=substeps, max_active=128)

    # warm path: first chunk compiles, the rest hit the cache — time
    # the cached chunks only (min-of-chunks capability statistic)
    r = runner()
    r.run_chunk(tapes[0])
    cached = []
    for tape in tapes[1:]:
        t0 = time.perf_counter()
        r.run_chunk(tape)
        cached.append(time.perf_counter() - t0)
    cached_s = min(cached)

    # naive driver: a recompile before every chunk
    r = runner()
    naive = []
    for tape in tapes[1:]:
        jaxsim.clear_cache()
        t0 = time.perf_counter()
        r.run_chunk(tape)
        naive.append(time.perf_counter() - t0)
    naive_s = min(naive)

    speedup = naive_s / cached_s
    print(f"chunk cache: cached {cached_s * 1e3:.1f}ms/chunk vs "
          f"naive-recompile {naive_s * 1e3:.0f}ms/chunk -> "
          f"{speedup:.0f}x")
    assert speedup >= MIN_SPEEDUP, \
        f"chunk-cache floor: expected >= {MIN_SPEEDUP}x, " \
        f"got {speedup:.2f}x"
    return {"chunk": chunk, "n_chunks": n_chunks,
            "cached_s": cached_s, "naive_recompile_s": naive_s,
            "speedup": speedup, "min_speedup": MIN_SPEEDUP}


def run_soak(n_tasks: int = 1_000_000, policy: str = "mc",
             lam: float = 60.0, interval_s: float = 3600.0,
             substeps: int = 2, chunk: int = 64, window: int = 256,
             capacity: int = 512, assert_steady: bool = True) -> dict:
    """The ≥10⁶-task steady-state run: one process, one compiled chunk
    executable, RSS and ring occupancy sampled every chunk."""
    from repro.env import jaxsim
    from repro.launch import experiments

    before = jaxsim.cache_stats()
    rss_series, chunk_walls = [], []
    last = [time.perf_counter()]

    def on_chunk(i, runner, rolling):
        now = time.perf_counter()
        chunk_walls.append(now - last[0])
        last[0] = now
        rss_series.append(_rss_mb())
        if i % 50 == 0:
            s = rolling.snapshot()
            print(f"chunk {i:5d}  intervals={runner.t0:7d}  "
                  f"rss={rss_series[-1]:.0f}MB  qps={s['qps']:.4f}/s  "
                  f"viol={s['violation_rate']:.3f}  "
                  f"occ={s['occupancy_mean']:.1f}", flush=True)

    wall0 = time.perf_counter()
    rep = experiments.run_stream(
        policy=policy, lam=lam, seed=0, target_tasks=n_tasks,
        chunk_intervals=chunk, max_active=capacity, interval_s=interval_s,
        substeps=substeps, window_intervals=window, on_chunk=on_chunk)
    wall_s = time.perf_counter() - wall0
    after = jaxsim.cache_stats()

    # one compile for the single chunk shape, hits ever after
    compiles = after["misses"] - before["misses"]
    assert compiles == 1, \
        f"expected exactly 1 stream compile, got {compiles}"

    # steady-state rate: exclude the compile-bearing first chunk
    steady_wall = wall_s - chunk_walls[0]
    steady = rep["finished"] / steady_wall
    rss_25 = rss_series[max(0, len(rss_series) // 4 - 1)]
    peak_rss = max(rss_series)
    rss_growth = peak_rss / rss_25 - 1.0
    h1 = rep["occupancy_mean_first_half"]
    h2 = rep["occupancy_mean_second_half"]
    occ_drift = abs(h2 - h1) / max(h1, 1e-9)

    out = {
        "policy": policy, "lam": lam, "interval_s": interval_s,
        "substeps": substeps, "chunk": chunk, "window": window,
        "capacity": capacity, "target_tasks": n_tasks,
        "offered": rep["offered"], "fed": rep["fed"],
        "feeder_overflow": rep["feeder_overflow"],
        "dropped": rep["dropped"], "finished": rep["finished"],
        "live": rep["live"], "n_chunks": rep["n_chunks"],
        "n_intervals": rep["n_intervals"],
        "wall_s": wall_s, "first_chunk_s": chunk_walls[0],
        "tasks_per_sec": rep["finished"] / wall_s,
        "steady_tasks_per_sec": steady,
        "rss_25_mb": rss_25, "peak_rss_mb": peak_rss,
        "rss_growth": rss_growth, "max_rss_growth": MAX_RSS_GROWTH,
        "max_occupancy": rep["max_occupancy"],
        "occupancy_mean_first_half": h1,
        "occupancy_mean_second_half": h2,
        "occupancy_drift": occ_drift,
        "max_occupancy_drift": MAX_OCCUPANCY_DRIFT,
        "rolling_last": rep["rolling"],
    }
    out.update({k: rep["summary"][k] for k in SUMMARY_KEYS})

    print(f"soak: {rep['finished']} tasks / {wall_s:.1f}s = "
          f"{steady:.0f} tasks/s steady "
          f"({rep['n_chunks']} chunks x {chunk} intervals)")
    print(f"admission: offered={rep['offered']} "
          f"overflow={rep['feeder_overflow']} dropped={rep['dropped']}")
    print(f"memory: rss@25% {rss_25:.0f}MB, peak {peak_rss:.0f}MB "
          f"({rss_growth:+.1%}); occupancy halves {h1:.1f}/{h2:.1f} "
          f"({occ_drift:+.1%})")

    assert rep["offered"] == rep["fed"] + rep["feeder_overflow"]
    assert rep["admitted"] == rep["finished"] + rep["live"]
    if assert_steady:
        # the flatness pins need the long horizon: a 10-chunk quick run
        # is all ramp-up (ring filling, allocator warm-up)
        assert occ_drift <= MAX_OCCUPANCY_DRIFT, \
            f"ring occupancy drifted {occ_drift:.1%} " \
            f"(> {MAX_OCCUPANCY_DRIFT:.0%}): not steady-state"
        assert rss_growth <= MAX_RSS_GROWTH, \
            f"RSS grew {rss_growth:.1%} past the 25% mark " \
            f"(> {MAX_RSS_GROWTH:.0%}): the serving loop leaks"
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI size: ~10^4 tasks (speedup floor + "
                         "accounting + one-compile assertions; the "
                         "RSS/occupancy flatness pins need the full "
                         "soak horizon)")
    ap.add_argument("--tasks", type=int, default=None,
                    help="override the soak task target")
    ap.add_argument("--policy", default="mc")
    ap.add_argument("--out", default="benchmarks/results/stream_serve.json")
    args = ap.parse_args()

    n_tasks = args.tasks or (10_000 if args.quick else 1_000_000)
    with _obs_scope("stream_serve", policy=args.policy, n_tasks=n_tasks):
        out = {"speedup": run_speedup()}
        out["soak"] = run_soak(n_tasks=n_tasks, policy=args.policy,
                               chunk=16 if args.quick else 64,
                               window=64 if args.quick else 256,
                               assert_steady=not args.quick)

    from repro.env import jaxsim
    out["cache_stats"] = {k: v for k, v in jaxsim.cache_stats().items()
                          if k != "keys"}
    out["provenance"] = provenance(policy=args.policy, n_tasks=n_tasks)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
