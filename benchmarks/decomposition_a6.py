"""Appendix A.6 reproduction: response time depends on the SPLIT decision
far more than on the PLACEMENT decision — the hypothesis that justifies
the paper's two-stage (MAB then DASO) decomposition.

For a panel of sampled tasks on a lightly loaded cluster we measure the
response time under {layer, semantic} × {K random feasible placements}
and compare the variance explained by the split decision against the
variance across placements (paper Fig. 19)."""
from __future__ import annotations

import argparse
import json
import os

try:
    from benchmarks._provenance import provenance
except ImportError:       # run as a loose script from benchmarks/
    from _provenance import provenance

import numpy as np

from repro.env.simulator import EdgeSim
from repro.env.workload import LAYER, SEMANTIC, Task


def measure(task_app, batch, decision, placement_seed, lam=2.0):
    sim = EdgeSim(lam=0.0, seed=17, substeps=20)
    # light background load
    sim.gen.lam = 0
    rng = np.random.RandomState(placement_seed)
    t = Task(id=0, app=task_app, batch=batch, sla_s=1e9, arrival_s=0.0)
    sim.gen.realize(t, decision)
    sim.active.append(t)
    t.placed = True
    workers = rng.choice(sim.cluster.n, size=len(t.fragments), replace=False)
    for f, w in zip(t.fragments, workers):
        f.worker = int(w)
    for _ in range(400):
        sim.advance()
        if t.done:
            return t.response_s
    raise RuntimeError("task did not finish")


def run(n_tasks=12, n_placements=5, out_json=None):
    rng = np.random.RandomState(0)
    rows = []
    for i in range(n_tasks):
        app = int(rng.randint(0, 3))
        batch = int(rng.randint(16000, 64001))
        per_dec = {}
        for dec, name in ((LAYER, "layer"), (SEMANTIC, "semantic")):
            rs = [measure(app, batch, dec, 100 + k)
                  for k in range(n_placements)]
            per_dec[name] = rs
        rows.append(dict(app=app, batch=batch, **per_dec))
    layer_means = np.array([np.mean(r["layer"]) for r in rows])
    sem_means = np.array([np.mean(r["semantic"]) for r in rows])
    split_gap = np.abs(layer_means - sem_means)
    placement_spread = np.array(
        [np.std(r["layer"]) + np.std(r["semantic"]) for r in rows]) / 2.0
    ratio = float(np.mean(split_gap) / max(np.mean(placement_spread), 1e-9))
    out = dict(
        mean_split_gap_s=float(np.mean(split_gap)),
        mean_placement_spread_s=float(np.mean(placement_spread)),
        split_over_placement_ratio=ratio,
        n_tasks=n_tasks, n_placements=n_placements,
    )
    print(f"split-decision gap      : {out['mean_split_gap_s']:.0f} s")
    print(f"placement spread (std)  : {out['mean_placement_spread_s']:.0f} s")
    print(f"ratio (split/placement) : {ratio:.1f}x")
    assert ratio > 2.0, "decomposition hypothesis should hold"
    out["provenance"] = provenance()
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        json.dump(out, open(out_json, "w"), indent=1)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/results/decomposition_a6.json")
    args = ap.parse_args()
    run(out_json=args.out)
