"""Aggregate the dry-run JSONs into the §Roofline table (EXPERIMENTS.md).

Reads benchmarks/results/dryrun/*.json produced by
``python -m repro.launch.dryrun --all``.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_all(d="benchmarks/results/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def table(rows, mesh="16x16"):
    rows = [r for r in rows if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    hdr = (f"{'arch':20s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'bound':>10s} {'peak_GB':>8s} {'useful':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        rl = r["roofline"]
        lines.append(
            f"{r['arch']:20s} {r['shape']:12s} {rl['compute_s']:9.4f} "
            f"{rl['memory_s']:9.4f} {rl['collective_s']:9.4f} "
            f"{rl['bottleneck']:>10s} {r['memory']['peak_gb']:8.2f} "
            f"{r['useful_flops_ratio']:7.2f}")
    return "\n".join(lines)


def markdown(rows, mesh="16x16"):
    rows = [r for r in rows if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = ["| arch | shape | compute s | memory s | collective s | bottleneck "
           "| peak GB/chip | useful FLOP ratio | 1-line fix |", "|" + "---|" * 9]
    for r in rows:
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4f} | "
            f"{rl['memory_s']:.4f} | {rl['collective_s']:.4f} | "
            f"{rl['bottleneck']} | {r['memory']['peak_gb']:.2f} | "
            f"{r['useful_flops_ratio']:.2f} | {suggest(r)} |")
    return "\n".join(out)


def suggest(r):
    b = r["roofline"]["bottleneck"]
    if b == "compute":
        if r["useful_flops_ratio"] < 0.4:
            return "cut non-model FLOPs (dispatch/remat/causal-skip)"
        return "increase per-chip batch or cut remat recompute"
    if b == "memory":
        return "fuse elementwise chains; bf16 scan inputs; bigger blocks"
    return "overlap collectives; shrink all-gathered dims; 2D sharding"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load_all(args.dir)
    if not rows:
        print("no dry-run results found; run python -m repro.launch.dryrun --all")
        return
    print((markdown if args.md else table)(rows, args.mesh))


if __name__ == "__main__":
    main()
