"""Learned-policy batched-grid benchmark: in-kernel SplitPlace vs host loop.

PR 2's batched backend only covered static BestFit policies; this
benchmark pins the PR 3 claim — the *learned* SplitPlace policy (online
MAB decider + array-form DASO placer) running inside the jitted interval
kernel.  Two measurements over (seed × λ) dual-trace grids:

  * **parity** — the 8-trace acceptance grid run through
    ``run_grid_arrays_learned`` must match per-trace host-loop replays
    (``replay_trace_edgesim_learned``: EdgeSim physics + the identical
    shared MAB/DASO pure functions) within ``allclose(rtol=1e-4)`` on
    every summary metric, including the final carried-MAB scalars;
  * **throughput** — warm traces/sec of the one-compiled-call batched
    backend vs looping the host replay over the same cells (the batched
    path must clear 3×; in practice the win is far larger because the
    host loop pays a Python round trip per interval for the surrogate
    ascent and MAB feedback).

The MAB state and DASO surrogate come from a real §6.3 host pretraining
pass (``launch.experiments.pretrain``), i.e. the same states a Table-4
SplitPlace row would deploy.

``--train`` benchmarks PR 4's claim instead: the full in-kernel
*training* loop (``mode="train"`` — ε-greedy MAB decisions + online
DASO finetuning in the interval carry) vs looping the host training
replay (``replay_trace_edgesim_trained``), parity extended to the
finetuned theta and the same floor on the 8-trace grid.

``--baselines`` benchmarks the unified-engine arms PR 5 brought
in-kernel — the Gillis contextual Q-learner and the decision-blind
MAB+GOBI ablation — against their host oracles
(``replay_trace_edgesim_gillis`` / ``replay_trace_edgesim_learned``
with a blind config), under the same parity + throughput contract.

Every mode enforces ``MIN_SPEEDUP`` (≥3× traces/sec vs the host loop)
as a hard floor, so a driver-unification or engine change cannot
silently regress the compiled hot path — the ``--quick`` CI runs fail
the build when the floor breaks.

``PYTHONPATH=src python -m benchmarks.jaxsim_learned
    [--quick] [--train] [--baselines]``
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import time

try:
    from benchmarks._provenance import obs_scope as _obs_scope
    from benchmarks._provenance import provenance
except ImportError:       # run as a loose script from benchmarks/
    from _provenance import obs_scope as _obs_scope
    from _provenance import provenance

import numpy as np

PARITY_KEYS = ("accuracy", "sla_violations", "reward", "response_intervals",
               "wait_intervals", "exec_intervals", "energy_mwhr", "fairness",
               "cost_per_container", "layer_fraction", "tasks_completed",
               "mab_eps", "mab_rho", "mab_t")

GILLIS_PARITY_KEYS = PARITY_KEYS[:-3] + ("gillis_eps",)

#: hard throughput floor — batched traces/sec must clear this multiple
#: of the host loop on the 8-trace acceptance grid, in every mode
MIN_SPEEDUP = 3.0

#: hard ceiling on the warm-path cost of ``telemetry="interval"`` vs
#: ``"summary"`` on the 8-trace grid (interleaved min-of-N on both
#: modes) — the in-carry series must stay within 5% of free
MAX_TELEMETRY_OVERHEAD = 0.05

def grid_cells(n: int):
    """First ``n`` cells of the canonical (λ × seed) benchmark grid."""
    lams, seeds = (2.0, 4.0, 6.0, 8.0), tuple(range(16))
    return list(itertools.product(lams, seeds))[:n] if n != 8 else \
        [(l, s) for l in lams for s in (0, 1)]


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _parity(refs, outs, check_theta=False, keys=PARITY_KEYS,
            tree_keys=()):
    """Shared cross-backend parity check: allclose(rtol=1e-4) over
    ``keys`` (optionally incl. pytree payloads — the finetuned theta,
    the Gillis Q-table) plus the dropped-task count; returns
    (ok, max_rel_err, dropped)."""
    import jax
    tree_keys = tuple(tree_keys) + (("daso_theta",) if check_theta else ())
    max_rel, ok = 0.0, True
    for ref, b in zip(refs, outs):
        for k in keys:
            denom = max(abs(ref[k]), 1e-12)
            max_rel = max(max_rel, abs(ref[k] - b[k]) / denom)
            if not np.isclose(ref[k], b[k], rtol=1e-4, atol=1e-9):
                ok = False
        for tk in tree_keys:
            for x, y in zip(jax.tree_util.tree_leaves(ref[tk]),
                            jax.tree_util.tree_leaves(b[tk])):
                if not np.allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-9):
                    ok = False
    dropped = sum(b["dropped_tasks"] for b in outs)
    return ok, max_rel, dropped


def run(n_intervals=20, substeps=10, sizes=(1, 8, 16), max_active=96,
        pretrain_intervals=16, pretrain_substeps=5, out_json=None,
        telemetry="summary", profile_dir=None):
    from repro.env import jaxsim
    from repro.launch import experiments

    with _obs_scope("jaxsim_learned", telemetry=telemetry,
                    profile_dir=profile_dir) as led:
        out = _run_ledgered(jaxsim, experiments, led, n_intervals,
                            substeps, sizes, max_active,
                            pretrain_intervals, pretrain_substeps,
                            telemetry, profile_dir)
    out["cache_stats"] = {k: v for k, v in jaxsim.cache_stats().items()
                          if k != "keys"}
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(out, f, indent=1)
    return out


def _run_ledgered(jaxsim, experiments, led, n_intervals, substeps, sizes,
                  max_active, pretrain_intervals, pretrain_substeps,
                  telemetry, profile_dir):
    t0 = time.perf_counter()
    pre = experiments.pretrain(pretrain_intervals, lam=5.0, seed=7,
                               substeps=pretrain_substeps)
    pretrain_s = time.perf_counter() - t0
    print(f"pretrain ({pretrain_intervals} intervals): {pretrain_s:.1f}s")

    def compile_cells(cells):
        return [jaxsim.compile_trace_dual(lam=lam, seed=seed,
                                          n_intervals=n_intervals,
                                          substeps=substeps)
                for lam, seed in cells]

    def batched(traces, tel=telemetry):
        return jaxsim.run_grid_arrays_learned(
            traces, pre.mab_state, daso_theta=pre.daso_theta,
            daso_cfg=pre.daso_cfg, max_active=max_active, telemetry=tel)

    def host_loop(traces):
        return [jaxsim.replay_trace_edgesim_learned(
            tr, pre.mab_state, daso_theta=pre.daso_theta,
            daso_cfg=pre.daso_cfg) for tr in traces]

    out = {"policy": "splitplace", "n_intervals": n_intervals,
           "substeps": substeps, "max_active": max_active,
           "pretrain_intervals": pretrain_intervals,
           "pretrain_s": pretrain_s}

    # ---- parity: 8-trace acceptance grid vs per-trace host replay ------
    traces8 = compile_cells(grid_cells(8))
    t0 = time.perf_counter()
    batched8 = batched(traces8)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    refs8 = host_loop(traces8)           # timed: reused as the 8-trace
    host8_s = time.perf_counter() - t0   # throughput sample below
    ok, max_rel, dropped = _parity(refs8, batched8)
    out["parity"] = {"allclose_rtol1e4": ok, "max_rel_err": max_rel,
                     "dropped_tasks": dropped, "n_traces": len(traces8)}
    print(f"parity (8-trace grid): allclose={ok} "
          f"max_rel_err={max_rel:.2e} dropped={dropped}")
    assert ok and dropped == 0, "learned-policy jaxsim parity failure"

    # ---- throughput: batched one-call vs host interval loop ------------
    # batched side is min-of-N (machine-noise capability statistic); the
    # host loop is ~2 orders slower per trace, one sample is plenty —
    # and the 8-trace grid reuses the parity pass's host sample instead
    # of paying for the slow loop twice
    out["grids"] = {}
    for size in sizes:
        traces = traces8 if size == 8 else compile_cells(grid_cells(size))
        batched(traces)                       # warm/compile
        tb = min(_timed(lambda: batched(traces)) for _ in range(3))
        th = host8_s if size == 8 else _timed(lambda: host_loop(traces))
        rec = {"batched_s": tb, "batched_traces_per_sec": size / tb,
               "host_s": th, "host_traces_per_sec": size / th,
               "speedup": th / tb}
        out["grids"][str(size)] = rec
        print(f"grid {size:3d}: batched {size / tb:7.1f} tr/s  "
              f"host {size / th:6.2f} tr/s  speedup {th / tb:7.1f}x")

    g8 = out["grids"].get("8")
    if g8:
        out["speedup_8_traces"] = g8["speedup"]
        print(f"8-trace grid speedup: {g8['speedup']:.1f}x "
              f"(compile+first-call {compile_s:.1f}s, amortized across "
              f"every later grid of the same shape)")
        assert g8["speedup"] >= MIN_SPEEDUP, \
            f"throughput floor: expected >= {MIN_SPEEDUP}x, " \
            f"got {g8['speedup']:.2f}x"

    # ---- telemetry overhead: the in-carry series must be ~free ---------
    # interleaved min-of-N on both modes (shared-CPU containers see
    # different machine windows back-to-back); the ceiling is a hard
    # floor-style assertion so the series can never silently tax the
    # compiled hot path
    tel8 = batched(traces8, tel="interval")   # warm/compile interval mode
    batched(traces8, tel="summary")           # warm (cache hit)
    t_sum, t_int = [], []
    for _ in range(5):
        t_sum.append(_timed(lambda: batched(traces8, tel="summary")))
        t_int.append(_timed(lambda: batched(traces8, tel="interval")))
    overhead = min(t_int) / min(t_sum) - 1.0
    out["telemetry"] = {"mode": telemetry,
                        "summary_s": min(t_sum), "interval_s": min(t_int),
                        "overhead_8_traces": overhead,
                        "max_overhead": MAX_TELEMETRY_OVERHEAD}
    print(f"telemetry overhead (8-trace grid): {overhead * 100:+.1f}% "
          f"(summary {min(t_sum):.3f}s, interval {min(t_int):.3f}s)")
    assert overhead <= MAX_TELEMETRY_OVERHEAD, \
        f"telemetry overhead ceiling: expected <= " \
        f"{MAX_TELEMETRY_OVERHEAD:.0%}, got {overhead:.1%}"
    led.add_series("trace0", tel8[0]["telemetry"]["cols"],
                   tel8[0]["telemetry"]["series"])

    if profile_dir:
        with led.profile(profile_dir):
            batched(traces8)

    out["provenance"] = provenance(telemetry=telemetry)
    return out


def run_train(n_intervals=40, substeps=5, max_active=160,
              pretrain_intervals=16, pretrain_substeps=5, out_json=None,
              train_hp=None, telemetry="summary"):
    """mode="train" measurement: the FULL §6.3 training loop — ε-greedy
    MAB decisions + in-kernel DASO finetuning — batched in the jitted
    kernel vs looping the host training replay
    (``replay_trace_edgesim_trained``) over the same 8 dual-trace cells.
    Parity covers every summary metric, the final MAB scalars AND the
    finetuned DASO theta; the acceptance bar is ≥3× traces/sec (in
    practice far larger: the host loop pays per-interval Python round
    trips for the surrogate ascent AND the weighted train epochs).

    The default 40-interval horizon opens the host-default cold-start
    gates (place_min=32), so the *finetuned-surrogate-ascended*
    placement path is exercised; ``--quick`` shortens the horizon and
    lowers the gates via ``train_hp`` instead, keeping the same path
    coverage at CI cost."""
    from repro.env import jaxsim
    from repro.launch import experiments

    train_hp = train_hp or jaxsim.TRAIN_HP

    t0 = time.perf_counter()
    pre = experiments.pretrain(pretrain_intervals, lam=5.0, seed=7,
                               substeps=pretrain_substeps)
    pretrain_s = time.perf_counter() - t0
    print(f"pretrain ({pretrain_intervals} intervals): {pretrain_s:.1f}s")

    traces = [jaxsim.compile_trace_dual(lam=lam, seed=seed,
                                        n_intervals=n_intervals,
                                        substeps=substeps)
              for lam, seed in grid_cells(8)]

    def batched():
        return jaxsim.run_grid_arrays_trained(
            traces, pre.mab_state, daso_theta=pre.daso_theta,
            daso_cfg=pre.daso_cfg, daso_opt_state=pre.daso_opt_state,
            max_active=max_active, train_hp=train_hp, telemetry=telemetry)

    def host_loop():
        return [jaxsim.replay_trace_edgesim_trained(
            tr, pre.mab_state, daso_theta=pre.daso_theta,
            daso_cfg=pre.daso_cfg, daso_opt_state=pre.daso_opt_state,
            train_hp=train_hp) for tr in traces]

    t0 = time.perf_counter()
    b8 = batched()
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    refs = host_loop()
    host_s = time.perf_counter() - t0

    ok, max_rel, dropped = _parity(refs, b8, check_theta=True)
    print(f"train parity (8-trace grid incl. theta): allclose={ok} "
          f"max_rel_err={max_rel:.2e} dropped={dropped}")
    assert ok and dropped == 0, "train-mode jaxsim parity failure"

    tb = min(_timed(batched) for _ in range(3))
    speedup = host_s / tb
    print(f"train grid 8: batched {8 / tb:7.1f} tr/s  "
          f"host {8 / host_s:6.2f} tr/s  speedup {speedup:7.1f}x "
          f"(compile+first-call {compile_s:.1f}s)")
    assert speedup >= MIN_SPEEDUP, \
        f"throughput floor: expected >= {MIN_SPEEDUP}x, " \
        f"got {speedup:.2f}x"

    out = {"policy": "splitplace", "mode": "train",
           "n_intervals": n_intervals, "substeps": substeps,
           "max_active": max_active, "train_hp": list(train_hp),
           "pretrain_s": pretrain_s,
           "parity": {"allclose_rtol1e4": ok, "max_rel_err": max_rel,
                      "dropped_tasks": dropped, "n_traces": 8},
           "batched_s": tb, "batched_traces_per_sec": 8 / tb,
           "host_s": host_s, "host_traces_per_sec": 8 / host_s,
           "speedup_8_traces": speedup}
    out["provenance"] = provenance()
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(out, f, indent=1)
    return out


def run_baselines(n_intervals=20, substeps=10, max_active=96,
                  pretrain_intervals=16, pretrain_substeps=5,
                  out_json=None, telemetry="summary"):
    """The unified-engine baseline arms — the in-kernel Gillis
    contextual Q-learner and the decision-blind MAB+GOBI ablation —
    under the same parity + ``MIN_SPEEDUP`` throughput contract as the
    SplitPlace arms, on the 8-trace acceptance grid.  Gillis' parity
    covers the final Q-table and ε; GOBI's the final MAB scalars.  The
    floor makes the engine unification's hot path a CI invariant for
    the new arms too."""
    from repro.env import jaxsim
    from repro.env.workload import COMPRESSED, LAYER
    from repro.launch import experiments

    out = {"n_intervals": n_intervals, "substeps": substeps,
           "max_active": max_active, "arms": {}}

    # ---- gillis: no pretraining products needed ------------------------
    gtr = [jaxsim.compile_trace_dual(lam=lam, seed=seed,
                                     n_intervals=n_intervals,
                                     substeps=substeps,
                                     variants=(LAYER, COMPRESSED))
           for lam, seed in grid_cells(8)]

    def g_batched():
        return jaxsim.run_grid_arrays_gillis(gtr, max_active=max_active,
                                             telemetry=telemetry)

    def g_host():
        return [jaxsim.replay_trace_edgesim_gillis(tr) for tr in gtr]

    b8 = g_batched()                       # warm/compile
    t0 = time.perf_counter()
    refs = g_host()
    host_s = time.perf_counter() - t0
    ok, max_rel, dropped = _parity(refs, b8, keys=GILLIS_PARITY_KEYS,
                                   tree_keys=("gillis_q",))
    print(f"gillis parity (8-trace grid incl. Q/ε): allclose={ok} "
          f"max_rel_err={max_rel:.2e} dropped={dropped}")
    assert ok and dropped == 0, "gillis jaxsim parity failure"
    tb = min(_timed(g_batched) for _ in range(3))
    speedup = host_s / tb
    print(f"gillis grid 8: batched {8 / tb:7.1f} tr/s  "
          f"host {8 / host_s:6.2f} tr/s  speedup {speedup:7.1f}x")
    assert speedup >= MIN_SPEEDUP, \
        f"gillis throughput floor: expected >= {MIN_SPEEDUP}x, " \
        f"got {speedup:.2f}x"
    out["arms"]["gillis"] = {
        "parity": {"allclose_rtol1e4": ok, "max_rel_err": max_rel},
        "batched_traces_per_sec": 8 / tb, "host_traces_per_sec": 8 / host_s,
        "speedup_8_traces": speedup}

    # ---- mab+gobi: blind surrogate from a real pretraining pass --------
    pre = experiments.pretrain(pretrain_intervals, lam=5.0, seed=7,
                               substeps=pretrain_substeps)
    blind = pre.daso_cfg._replace(decision_aware=False)
    btr = [jaxsim.compile_trace_dual(lam=lam, seed=seed,
                                     n_intervals=n_intervals,
                                     substeps=substeps)
           for lam, seed in grid_cells(8)]

    def b_batched():
        return jaxsim.run_grid_arrays_learned(
            btr, pre.mab_state, daso_theta=pre.daso_theta, daso_cfg=blind,
            max_active=max_active, telemetry=telemetry)

    def b_host():
        return [jaxsim.replay_trace_edgesim_learned(
            tr, pre.mab_state, daso_theta=pre.daso_theta, daso_cfg=blind)
            for tr in btr]

    b8 = b_batched()                       # warm/compile
    t0 = time.perf_counter()
    refs = b_host()
    host_s = time.perf_counter() - t0
    ok, max_rel, dropped = _parity(refs, b8)
    print(f"mab+gobi parity (8-trace grid): allclose={ok} "
          f"max_rel_err={max_rel:.2e} dropped={dropped}")
    assert ok and dropped == 0, "mab+gobi jaxsim parity failure"
    tb = min(_timed(b_batched) for _ in range(3))
    speedup = host_s / tb
    print(f"mab+gobi grid 8: batched {8 / tb:7.1f} tr/s  "
          f"host {8 / host_s:6.2f} tr/s  speedup {speedup:7.1f}x")
    assert speedup >= MIN_SPEEDUP, \
        f"mab+gobi throughput floor: expected >= {MIN_SPEEDUP}x, " \
        f"got {speedup:.2f}x"
    out["arms"]["mab+gobi"] = {
        "parity": {"allclose_rtol1e4": ok, "max_rel_err": max_rel},
        "batched_traces_per_sec": 8 / tb, "host_traces_per_sec": 8 / host_s,
        "speedup_8_traces": speedup}

    out["provenance"] = provenance()
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(out, f, indent=1)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (parity + the 8-trace grid)")
    ap.add_argument("--train", action="store_true",
                    help="benchmark mode='train' (in-kernel ε-greedy MAB "
                         "+ DASO finetuning) instead of deploy mode")
    ap.add_argument("--baselines", action="store_true",
                    help="benchmark the in-kernel baseline arms (gillis, "
                         "mab+gobi) instead of the SplitPlace arms")
    ap.add_argument("--telemetry", default="summary",
                    choices=("summary", "interval"),
                    help="run the measured grids with the in-carry "
                         "interval telemetry series on (the overhead "
                         "check always measures both modes)")
    ap.add_argument("--profile-dir", default=None,
                    help="also capture a jax.profiler trace of one warm "
                         "grid call under this directory")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.baselines:
        out = args.out or "benchmarks/results/jaxsim_baselines.json"
        with _obs_scope("jaxsim_baselines", telemetry=args.telemetry):
            if args.quick:
                run_baselines(n_intervals=10, substeps=5, max_active=96,
                              pretrain_intervals=8, out_json=out,
                              telemetry=args.telemetry)
            else:
                run_baselines(out_json=out, telemetry=args.telemetry)
        return
    if args.train:
        out = args.out or "benchmarks/results/jaxsim_learned_train.json"
        with _obs_scope("jaxsim_learned_train", telemetry=args.telemetry):
            if args.quick:
                # short horizon + open gates: same path coverage, CI cost
                run_train(n_intervals=12, substeps=5, max_active=96,
                          train_hp=(0.5, 0.5, 4, 6, 4), out_json=out,
                          telemetry=args.telemetry)
            else:
                run_train(out_json=out, telemetry=args.telemetry)
        return
    out = args.out or "benchmarks/results/jaxsim_learned.json"
    if args.quick:
        run(sizes=(8,), out_json=out, telemetry=args.telemetry,
            profile_dir=args.profile_dir)
    else:
        run(out_json=out, telemetry=args.telemetry,
            profile_dir=args.profile_dir)


if __name__ == "__main__":
    main()
