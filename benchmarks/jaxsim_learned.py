"""Learned-policy batched-grid benchmark: in-kernel SplitPlace vs host loop.

PR 2's batched backend only covered static BestFit policies; this
benchmark pins the PR 3 claim — the *learned* SplitPlace policy (online
MAB decider + array-form DASO placer) running inside the jitted interval
kernel.  Two measurements over (seed × λ) dual-trace grids:

  * **parity** — the 8-trace acceptance grid run through
    ``run_grid_arrays_learned`` must match per-trace host-loop replays
    (``replay_trace_edgesim_learned``: EdgeSim physics + the identical
    shared MAB/DASO pure functions) within ``allclose(rtol=1e-4)`` on
    every summary metric, including the final carried-MAB scalars;
  * **throughput** — warm traces/sec of the one-compiled-call batched
    backend vs looping the host replay over the same cells (the batched
    path must clear 3×; in practice the win is far larger because the
    host loop pays a Python round trip per interval for the surrogate
    ascent and MAB feedback).

The MAB state and DASO surrogate come from a real §6.3 host pretraining
pass (``launch.experiments.pretrain``), i.e. the same states a Table-4
SplitPlace row would deploy.

``PYTHONPATH=src python -m benchmarks.jaxsim_learned [--quick]``
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import time

import numpy as np

PARITY_KEYS = ("accuracy", "sla_violations", "reward", "response_intervals",
               "wait_intervals", "exec_intervals", "energy_mwhr", "fairness",
               "cost_per_container", "layer_fraction", "tasks_completed",
               "mab_eps", "mab_rho", "mab_t")


def grid_cells(n: int):
    """First ``n`` cells of the canonical (λ × seed) benchmark grid."""
    lams, seeds = (2.0, 4.0, 6.0, 8.0), tuple(range(16))
    return list(itertools.product(lams, seeds))[:n] if n != 8 else \
        [(l, s) for l in lams for s in (0, 1)]


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(n_intervals=20, substeps=10, sizes=(1, 8, 16), max_active=96,
        pretrain_intervals=16, pretrain_substeps=5, out_json=None):
    from repro.env import jaxsim
    from repro.launch import experiments

    t0 = time.perf_counter()
    pre = experiments.pretrain(pretrain_intervals, lam=5.0, seed=7,
                               substeps=pretrain_substeps)
    pretrain_s = time.perf_counter() - t0
    print(f"pretrain ({pretrain_intervals} intervals): {pretrain_s:.1f}s")

    def compile_cells(cells):
        return [jaxsim.compile_trace_dual(lam=lam, seed=seed,
                                          n_intervals=n_intervals,
                                          substeps=substeps)
                for lam, seed in cells]

    def batched(traces):
        return jaxsim.run_grid_arrays_learned(
            traces, pre.mab_state, daso_theta=pre.daso_theta,
            daso_cfg=pre.daso_cfg, max_active=max_active)

    def host_loop(traces):
        return [jaxsim.replay_trace_edgesim_learned(
            tr, pre.mab_state, daso_theta=pre.daso_theta,
            daso_cfg=pre.daso_cfg) for tr in traces]

    out = {"policy": "splitplace", "n_intervals": n_intervals,
           "substeps": substeps, "max_active": max_active,
           "pretrain_intervals": pretrain_intervals,
           "pretrain_s": pretrain_s}

    # ---- parity: 8-trace acceptance grid vs per-trace host replay ------
    traces8 = compile_cells(grid_cells(8))
    t0 = time.perf_counter()
    batched8 = batched(traces8)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    refs8 = host_loop(traces8)           # timed: reused as the 8-trace
    host8_s = time.perf_counter() - t0   # throughput sample below
    max_rel, ok = 0.0, True
    for ref, b in zip(refs8, batched8):
        for k in PARITY_KEYS:
            denom = max(abs(ref[k]), 1e-12)
            max_rel = max(max_rel, abs(ref[k] - b[k]) / denom)
            if not np.isclose(ref[k], b[k], rtol=1e-4, atol=1e-9):
                ok = False
    dropped = sum(b["dropped_tasks"] for b in batched8)
    out["parity"] = {"allclose_rtol1e4": ok, "max_rel_err": max_rel,
                     "dropped_tasks": dropped, "n_traces": len(traces8)}
    print(f"parity (8-trace grid): allclose={ok} "
          f"max_rel_err={max_rel:.2e} dropped={dropped}")
    assert ok and dropped == 0, "learned-policy jaxsim parity failure"

    # ---- throughput: batched one-call vs host interval loop ------------
    # batched side is min-of-N (machine-noise capability statistic); the
    # host loop is ~2 orders slower per trace, one sample is plenty —
    # and the 8-trace grid reuses the parity pass's host sample instead
    # of paying for the slow loop twice
    out["grids"] = {}
    for size in sizes:
        traces = traces8 if size == 8 else compile_cells(grid_cells(size))
        batched(traces)                       # warm/compile
        tb = min(_timed(lambda: batched(traces)) for _ in range(3))
        th = host8_s if size == 8 else _timed(lambda: host_loop(traces))
        rec = {"batched_s": tb, "batched_traces_per_sec": size / tb,
               "host_s": th, "host_traces_per_sec": size / th,
               "speedup": th / tb}
        out["grids"][str(size)] = rec
        print(f"grid {size:3d}: batched {size / tb:7.1f} tr/s  "
              f"host {size / th:6.2f} tr/s  speedup {th / tb:7.1f}x")

    g8 = out["grids"].get("8")
    if g8:
        out["speedup_8_traces"] = g8["speedup"]
        print(f"8-trace grid speedup: {g8['speedup']:.1f}x "
              f"(compile+first-call {compile_s:.1f}s, amortized across "
              f"every later grid of the same shape)")
        assert g8["speedup"] >= 3.0, \
            f"acceptance: expected >= 3x, got {g8['speedup']:.2f}x"

    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(out, f, indent=1)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (parity + the 8-trace grid)")
    ap.add_argument("--out", default="benchmarks/results/jaxsim_learned.json")
    args = ap.parse_args()
    if args.quick:
        run(sizes=(8,), out_json=args.out)
    else:
        run(out_json=args.out)


if __name__ == "__main__":
    main()
