"""Shared run-provenance stamp for benchmark JSON artifacts.

Every ``benchmarks/*.py`` writer embeds ``provenance(...)`` in its
artifact so merged trajectories (``tools/bench_summary.py``) stay
comparable across machines and dispatch configurations: the jax version
and device fleet the numbers were measured on, plus the jitted
simulator's dispatch knobs (``substep_impl``, ``devices``) the run was
configured with.  Pass knobs as keyword overrides; unpassed knobs record
the process-wide defaults (env var / single-dispatch).
"""
from __future__ import annotations

import os


def provenance(**knobs) -> dict:
    import jax
    prov = {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": jax.devices()[0].device_kind,
        "cpu_count": os.cpu_count(),
        # the jitted simulator's dispatch knobs; None devices = the
        # host thread-chunk dispatcher (no device mesh)
        "substep_impl": os.environ.get("JAXSIM_SUBSTEP_IMPL", "xla"),
        "devices": None,
    }
    prov.update(knobs)
    return prov
