"""Shared run-provenance stamp + ledger scope for benchmark artifacts.

Every ``benchmarks/*.py`` writer embeds ``provenance(...)`` in its
artifact so merged trajectories (``tools/bench_summary.py``) stay
comparable across machines and dispatch configurations.  The stamp
itself lives in ``repro.obs.provenance_stamp`` — one helper shared with
the run-ledger tracer — and this module is the import-stable benchmark
alias.  Pass knobs as keyword overrides; unpassed knobs record the
process-wide defaults (env var / single-dispatch).

``obs_scope`` is the matching run-ledger wrapper: it routes the
driver's compile/dispatch/summarize spans and cache counters into a
fresh ``RunLedger`` for the block's duration and dumps it under
``benchmarks/results/obs/<name>.jsonl`` — the JSONL the CI workflow
uploads and ``tools/obs_report.py`` renders.
"""
from __future__ import annotations

import os
from contextlib import contextmanager

#: where benchmark ledgers land (CI uploads ``obs/*.jsonl``)
OBS_DIR = "benchmarks/results/obs"


def provenance(**knobs) -> dict:
    from repro.obs import provenance_stamp
    return provenance_stamp(**knobs)


@contextmanager
def obs_scope(name: str, **stamp_knobs):
    """Route driver instrumentation into a fresh ledger for the block,
    then snapshot the runner-cache counters and dump the JSONL."""
    from repro.obs import RunLedger, use_ledger
    led = RunLedger(name)
    led.stamp(**stamp_knobs)
    try:
        with use_ledger(led):
            yield led
    finally:
        # dump even when an acceptance assertion aborts the run — the
        # ledger is most useful exactly then
        from repro.env.jaxsim import cache_stats
        led.add_cache_stats(cache_stats())
        led.dump(os.path.join(OBS_DIR, name + ".jsonl"))
