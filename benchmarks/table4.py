"""Table 4 reproduction: SplitPlace vs baselines & ablations.

Protocol mirrors §6: pretrain the MAB (and DASO replay) with feedback-based
ε-greedy for 200 intervals, then evaluate every policy for Γ=100 intervals
with λ=6 Poisson arrivals over the three applications; average over seeds.
"""
from __future__ import annotations

import argparse
import json
import os
import time

try:
    from benchmarks._provenance import provenance
except ImportError:       # run as a loose script from benchmarks/
    from _provenance import provenance

POLICIES = ["mc", "gillis", "semantic+gobi", "layer+gobi", "random+daso",
            "mab+gobi", "splitplace"]
PAPER = {  # Table 4 reference values
    "mc":            dict(reward=0.8398, viol=0.26, acc=0.8993, resp=6.85),
    "gillis":        dict(reward=0.8417, viol=0.22, acc=0.9190, resp=8.39),
    "semantic+gobi": dict(reward=0.8391, viol=0.14, acc=0.8904, resp=3.70),
    "layer+gobi":    dict(reward=0.6487, viol=0.62, acc=0.9317, resp=9.92),
    "random+daso":   dict(reward=0.8162, viol=0.29, acc=0.9071, resp=5.55),
    "mab+gobi":      dict(reward=0.9018, viol=0.10, acc=0.9145, resp=5.64),
    "splitplace":    dict(reward=0.9418, viol=0.08, acc=0.9272, resp=4.50),
}


def run(n_intervals=100, lam=6.0, seeds=(0, 1, 2), substeps=10,
        pretrain_intervals=200, out_json=None, quiet=False):
    from repro.launch.experiments import aggregate, run_grid
    t0 = time.time()
    # one shared §6.3 pretraining pass (MAB ε-greedy + the Gillis
    # baseline's Q-learner on the same budget), then the policy × seed grid
    records = run_grid(POLICIES, seeds=seeds, lams=(lam,),
                       n_intervals=n_intervals, substeps=substeps,
                       pretrain_intervals=pretrain_intervals)
    rows = aggregate(records, by=("policy",))
    for pol in POLICIES:
        if not quiet:
            m = rows[pol]
            p = PAPER[pol]
            print(f"{pol:15s} reward={m['reward']:.4f} (paper {p['reward']:.4f}) "
                  f"viol={m['sla_violations']:.2f} ({p['viol']:.2f}) "
                  f"acc={m['accuracy']:.4f} ({p['acc']:.4f}) "
                  f"resp={m['response_intervals']:.2f} ({p['resp']:.2f}) "
                  f"energy={m['energy_mwhr']:.4f} fair={m['fairness']:.2f}")
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump({"rows": rows, "paper": PAPER,
                       "provenance": provenance(),
                       "elapsed_s": time.time() - t0}, f, indent=1)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--intervals", type=int, default=100)
    ap.add_argument("--seeds", type=int, nargs="*", default=[0, 1, 2])
    ap.add_argument("--substeps", type=int, default=10)
    ap.add_argument("--out", default="benchmarks/results/table4.json")
    args = ap.parse_args()
    run(n_intervals=args.intervals, seeds=tuple(args.seeds),
        substeps=args.substeps, out_json=args.out)


if __name__ == "__main__":
    main()
