"""Table 4 reproduction: SplitPlace vs baselines & ablations.

Protocol mirrors §6: pretrain the MAB (and DASO replay) with feedback-based
ε-greedy for 200 intervals, then evaluate every policy for Γ=100 intervals
with λ=6 Poisson arrivals over the three applications; average over seeds.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

POLICIES = ["mc", "gillis", "semantic+gobi", "layer+gobi", "random+daso",
            "mab+gobi", "splitplace"]
PAPER = {  # Table 4 reference values
    "mc":            dict(reward=0.8398, viol=0.26, acc=0.8993, resp=6.85),
    "gillis":        dict(reward=0.8417, viol=0.22, acc=0.9190, resp=8.39),
    "semantic+gobi": dict(reward=0.8391, viol=0.14, acc=0.8904, resp=3.70),
    "layer+gobi":    dict(reward=0.6487, viol=0.62, acc=0.9317, resp=9.92),
    "random+daso":   dict(reward=0.8162, viol=0.29, acc=0.9071, resp=5.55),
    "mab+gobi":      dict(reward=0.9018, viol=0.10, acc=0.9145, resp=5.64),
    "splitplace":    dict(reward=0.9418, viol=0.08, acc=0.9272, resp=4.50),
}


def run(n_intervals=100, lam=6.0, seeds=(0, 1, 2), substeps=10,
        pretrain_intervals=200, out_json=None, quiet=False):
    from repro.core.splitplace import pretrain_mab, run_experiment
    t0 = time.time()
    state, _ = pretrain_mab(n_intervals=pretrain_intervals, lam=lam,
                            substeps=substeps, seed=7)
    # pretrain the Gillis baseline's Q-learner for the same budget the
    # MAB gets (its eps decays over the pretraining run)
    gillis_pre = run_experiment("gillis", n_intervals=pretrain_intervals,
                                lam=lam, seed=7, substeps=substeps)
    gillis_policy = gillis_pre["policy_obj"]
    rows = {}
    for pol in POLICIES:
        agg = []
        for seed in seeds:
            ms = state if pol in ("splitplace", "mab+gobi") else None
            r = run_experiment(pol, n_intervals=n_intervals, lam=lam,
                               seed=seed, mab_state=ms, train=False,
                               substeps=substeps,
                               policy=gillis_policy if pol == "gillis" else None)
            r.pop("mab_state", None)
            r.pop("policy_obj", None)
            agg.append(r)
        rows[pol] = {k: float(np.mean([a[k] for a in agg]))
                     for k in agg[0]
                     if isinstance(agg[0][k], (int, float))
                     and not isinstance(agg[0][k], bool)}
        rows[pol]["reward_std"] = float(np.std([a["reward"] for a in agg]))
        if not quiet:
            m = rows[pol]
            p = PAPER[pol]
            print(f"{pol:15s} reward={m['reward']:.4f} (paper {p['reward']:.4f}) "
                  f"viol={m['sla_violations']:.2f} ({p['viol']:.2f}) "
                  f"acc={m['accuracy']:.4f} ({p['acc']:.4f}) "
                  f"resp={m['response_intervals']:.2f} ({p['resp']:.2f}) "
                  f"energy={m['energy_mwhr']:.4f} fair={m['fairness']:.2f}")
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump({"rows": rows, "paper": PAPER,
                       "elapsed_s": time.time() - t0}, f, indent=1)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--intervals", type=int, default=100)
    ap.add_argument("--seeds", type=int, nargs="*", default=[0, 1, 2])
    ap.add_argument("--substeps", type=int, default=10)
    ap.add_argument("--out", default="benchmarks/results/table4.json")
    args = ap.parse_args()
    run(n_intervals=args.intervals, seeds=tuple(args.seeds),
        substeps=args.substeps, out_json=args.out)


if __name__ == "__main__":
    main()
