"""Fig. 2 reproduction from first principles: REAL layer vs semantic splits
of trained classifiers — accuracy and (measured) latency per strategy."""
from __future__ import annotations

import argparse
import json
import os

try:
    from benchmarks._provenance import provenance
except ImportError:       # run as a loose script from benchmarks/
    from _provenance import provenance
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import splitnets as sn
from repro.data.pipeline import APPS, synthetic_classification


def run(apps=("mnist", "fashionmnist", "cifar100"), steps=500, out_json=None):
    rows = {}
    for app in apps:
        spec = APPS[app]
        big = spec.num_classes > 10
        depth = 2 if big else 4
        n_train = 20000 if big else 6000
        app_steps = max(steps, 800) if big else steps
        cfg = sn.ClassifierConfig(input_dim=spec.input_dim,
                                  num_classes=spec.num_classes,
                                  hidden=256, depth=depth)
        x, y = synthetic_classification(app, n_train, seed=0)
        xt, yt = synthetic_classification(app, 2000, seed=1)
        params = sn.train_classifier(jax.random.PRNGKey(0), cfg, x, y,
                                     steps=app_steps, batch=512)
        acc_full = sn.accuracy(params, xt, yt)

        frags = sn.layer_split(params, 3)
        t0 = time.perf_counter()
        for _ in range(5):
            sn.layer_split_apply(frags, jnp.asarray(xt)).block_until_ready()
        t_layer = (time.perf_counter() - t0) / 5
        out_l = sn.layer_split_apply(frags, jnp.asarray(xt))
        acc_layer = float((jnp.argmax(out_l, -1) == jnp.asarray(yt)).mean())

        nb = min(4, spec.num_classes)
        branches, groups = sn.train_semantic_split(
            jax.random.PRNGKey(1), cfg, x, y, num_branches=nb,
            steps=app_steps)
        cgroups, fgroups = groups
        for _ in range(5):
            # parallel branches: wall time of the SLOWEST branch models the
            # paper's parallel placement; measure the max single branch
            ts = []
            for b, (lo, hi) in zip(branches, fgroups):
                tb = time.perf_counter()
                sn.mlp_apply(b, jnp.asarray(xt[:, lo:hi])).block_until_ready()
                ts.append(time.perf_counter() - tb)
        t_sem = max(ts)
        logits = sn.semantic_split_apply(branches, groups, jnp.asarray(xt))
        acc_sem = float((jnp.argmax(logits, -1) == jnp.asarray(yt)).mean())

        rows[app] = dict(acc_full=acc_full, acc_layer=acc_layer,
                         acc_semantic=acc_sem,
                         latency_layer_ms=t_layer * 1e3,
                         latency_semantic_ms=t_sem * 1e3)
        print(f"{app:13s} acc full={acc_full:.3f} layer={acc_layer:.3f} "
              f"semantic={acc_sem:.3f} | latency layer={t_layer*1e3:.1f}ms "
              f"semantic={t_sem*1e3:.1f}ms")
        assert abs(acc_layer - acc_full) < 1e-9, "layer split must be exact"
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        json.dump({"rows": rows, "provenance": provenance()},
                  open(out_json, "w"), indent=1)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/results/splitnets_fig2.json")
    args = ap.parse_args()
    run(out_json=args.out)
