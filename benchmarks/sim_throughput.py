"""Simulator throughput: SoA kernels vs the seed per-object loops.

Runs the acceptance trace — 100 intervals, λ=24, substeps=30, BestFit
placement — through both ``repro.env.simulator.EdgeSim`` (vectorized
structure-of-arrays) and ``repro.env.legacy_sim.LegacyEdgeSim`` driven by
the seed's verbatim placer, and emits intervals/sec + speedup JSON for
the perf trajectory.  Also reports a 100-worker (2× Table 3 fleet) SoA
trace, which the seed simulator could not afford.

``PYTHONPATH=src python -m benchmarks.sim_throughput [--quick]``
"""
from __future__ import annotations

import argparse
import json
import os
import time

try:
    from benchmarks._provenance import provenance
except ImportError:       # run as a loose script from benchmarks/
    from _provenance import provenance


def run_trace(sim, placer, n_intervals):
    t0 = time.perf_counter()
    finished = 0
    for _ in range(n_intervals):
        tasks = sim.new_interval_tasks()
        sim.admit(tasks, [i % 3 for i in range(len(tasks))])
        sim.apply_placement(placer.place(sim))
        stats = sim.advance()
        finished += len(stats.finished)
    elapsed = time.perf_counter() - t0
    return elapsed, finished


def run(n_intervals=100, lam=24.0, substeps=30, seed=0, out_json=None,
        skip_legacy=False):
    from repro.core.splitplace import BestFitPlacer
    from repro.env.legacy_sim import LegacyBestFitPlacer, LegacyEdgeSim
    from repro.env.simulator import EdgeSim
    from repro.launch.experiments import make_scaled_cluster

    kw = dict(lam=lam, seed=seed, substeps=substeps)
    out = {"n_intervals": n_intervals, "lam": lam, "substeps": substeps}

    soa_s, fin_soa = run_trace(EdgeSim(**kw), BestFitPlacer(), n_intervals)
    out["soa"] = {"seconds": soa_s, "intervals_per_sec": n_intervals / soa_s,
                  "tasks_finished": fin_soa}
    print(f"soa     : {soa_s:7.2f}s  {n_intervals / soa_s:8.1f} intervals/s "
          f"({fin_soa} tasks)")

    if not skip_legacy:
        leg_s, fin_leg = run_trace(LegacyEdgeSim(**kw), LegacyBestFitPlacer(),
                                   n_intervals)
        out["legacy"] = {"seconds": leg_s,
                         "intervals_per_sec": n_intervals / leg_s,
                         "tasks_finished": fin_leg}
        out["speedup"] = leg_s / soa_s
        print(f"legacy  : {leg_s:7.2f}s  {n_intervals / leg_s:8.1f} "
              f"intervals/s ({fin_leg} tasks)")
        print(f"speedup : {out['speedup']:.1f}x")

    # 100-worker cluster (2x the Table 3 fleet) — SoA only; the legacy
    # loops made clusters of this size impractical
    big_s, fin_big = run_trace(
        EdgeSim(cluster=make_scaled_cluster(2), **kw), BestFitPlacer(),
        n_intervals)
    out["soa_100_workers"] = {"seconds": big_s,
                              "intervals_per_sec": n_intervals / big_s,
                              "tasks_finished": fin_big}
    print(f"soa x100w: {big_s:6.2f}s  {n_intervals / big_s:8.1f} intervals/s "
          f"({fin_big} tasks)")

    # 500-worker fleet (10x) — exercises the vectorized apply_placement
    # fast path (the sequential per-fragment repair was the hot spot here)
    huge_s, fin_huge = run_trace(
        EdgeSim(cluster=make_scaled_cluster(10), **kw), BestFitPlacer(),
        n_intervals)
    out["soa_500_workers"] = {"seconds": huge_s,
                              "intervals_per_sec": n_intervals / huge_s,
                              "tasks_finished": fin_huge}
    print(f"soa x500w: {huge_s:6.2f}s  {n_intervals / huge_s:8.1f} "
          f"intervals/s ({fin_huge} tasks)")

    # 1000-worker fleet (20x) — tracks the BestFitPlacer.place greedy at
    # scale.  The masked-argmax walk was benchmarked bit-exact against
    # candidate-window / heap / lazy-mask / closed-form-batch variants
    # and is the fastest form at this size (see the placer's 1000-worker
    # note); this case keeps its end-to-end cost measured.
    giant_s, fin_giant = run_trace(
        EdgeSim(cluster=make_scaled_cluster(20), **kw), BestFitPlacer(),
        n_intervals)
    out["soa_1000_workers"] = {"seconds": giant_s,
                               "intervals_per_sec": n_intervals / giant_s,
                               "tasks_finished": fin_giant}
    print(f"soa x1000w: {giant_s:5.2f}s  {n_intervals / giant_s:8.1f} "
          f"intervals/s ({fin_giant} tasks)")

    out["provenance"] = provenance()
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(out, f, indent=1)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="30-interval run for CI")
    ap.add_argument("--skip-legacy", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/sim_throughput.json")
    args = ap.parse_args()
    run(n_intervals=30 if args.quick else 100,
        skip_legacy=args.skip_legacy, out_json=args.out)


if __name__ == "__main__":
    main()
