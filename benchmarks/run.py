"""Benchmark entrypoint: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick]``

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus the
reproduction-vs-paper comparison blocks.
"""
from __future__ import annotations

import argparse
import sys
import time


def timed(name, fn, derived_fn=lambda r: ""):
    t0 = time.perf_counter()
    r = fn()
    us = (time.perf_counter() - t0) * 1e6
    print(f"CSV,{name},{us:.0f},{derived_fn(r)}", flush=True)
    return r


def bench_table4(quick):
    from benchmarks import table4
    kw = dict(n_intervals=30, seeds=(0,), substeps=6,
              pretrain_intervals=60) if quick else \
         dict(n_intervals=100, seeds=(0, 1, 2), substeps=10,
              pretrain_intervals=200)
    rows = table4.run(out_json="benchmarks/results/table4.json", **kw)
    sp = rows["splitplace"]
    return rows, f"splitplace_reward={sp['reward']:.4f};viol={sp['sla_violations']:.3f}"


def bench_splitnets(quick):
    from benchmarks import splitnets_fig2
    rows = splitnets_fig2.run(steps=120 if quick else 300,
                              out_json="benchmarks/results/splitnets_fig2.json")
    mn = rows["mnist"]
    return rows, (f"acc_layer={mn['acc_layer']:.3f};"
                  f"acc_sem={mn['acc_semantic']:.3f}")


def bench_serving(quick):
    from benchmarks import serving_plans
    s = serving_plans.run(n_requests=16 if quick else 40,
                          out_json="benchmarks/results/serving_plans.json")
    return s, f"speedup={s['speedup']:.2f};met={s['deadline_met_frac']:.2f}"


def bench_roofline(quick):
    from benchmarks import roofline
    rows = roofline.load_all()
    if rows:
        print(roofline.table(rows, "16x16"))
    return rows, f"n_dryrun_results={len(rows)}"


def bench_decomposition(quick):
    from benchmarks import decomposition_a6
    out = decomposition_a6.run(
        n_tasks=6 if quick else 12, n_placements=3 if quick else 5,
        out_json="benchmarks/results/decomposition_a6.json")
    return out, f"split_over_placement={out['split_over_placement_ratio']:.1f}x"


def bench_sim_throughput(quick):
    from benchmarks import sim_throughput
    out = sim_throughput.run(
        n_intervals=30 if quick else 100,
        out_json="benchmarks/results/sim_throughput.json")
    return out, (f"speedup={out['speedup']:.1f}x;"
                 f"ips={out['soa']['intervals_per_sec']:.0f}")


def bench_jaxsim_grid(quick):
    from benchmarks import jaxsim_grid
    out = jaxsim_grid.run(sizes=(1, 8) if quick else (1, 4, 8, 16, 32, 64),
                          out_json="benchmarks/results/jaxsim_grid.json")
    return out, (f"speedup8={out['speedup_8_traces']:.2f}x;"
                 f"max_rel_err={out['parity']['max_rel_err']:.1e}")


def bench_sensitivity(quick):
    from benchmarks import sensitivity
    out = {}
    out["lambda"] = sensitivity.sweep_lambda(
        lams=(2, 6) if quick else (2, 6, 12, 24),
        n_intervals=10 if quick else 40, substeps=5 if quick else 8)
    return out, "ok"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes for CI-style runs")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    benches = {
        "splitnets_fig2": bench_splitnets,
        "serving_plans": bench_serving,
        "table4": bench_table4,
        "roofline": bench_roofline,
        "decomposition_a6": bench_decomposition,
        "sensitivity_lambda": bench_sensitivity,
        "sim_throughput": bench_sim_throughput,
        "jaxsim_grid": bench_jaxsim_grid,
    }
    todo = args.only or list(benches)
    failures = []
    for name in todo:
        print(f"\n==== {name} ====", flush=True)
        try:
            r = benches[name](args.quick)
            timed(name, lambda: r, lambda rr: rr[1])
        except Exception as e:  # pragma: no cover
            failures.append((name, repr(e)))
            print(f"FAILED {name}: {e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
