"""Batched jitted-grid benchmark: traces/sec + parity vs the host loop.

Two measurements over (seed × λ) BestFit grids:

  * **parity** — the 8-trace acceptance grid run through
    ``run_grid_batched`` must match per-trace ``EdgeSim`` replays of the
    same compiled workloads within ``allclose(rtol=1e-4)`` on every
    summary metric;
  * **throughput** — warm traces/sec of the one-compiled-call batched
    backend for grids of 1–64 traces vs looping the host
    ``launch.experiments.run_trace`` over the same cells (the batched
    path must clear 3×).

``--devices N`` adds a third measurement: the shard_map grid dispatcher
(1-D ``"grid"`` device mesh, forced host devices on CPU) vs the same
whole-grid vmap on a single device — the ≥3× scaling floor is asserted
only when the host actually has ``N`` cores to back the forced devices
(timeshared cores can't speed anything up; the column is informational
there).

``PYTHONPATH=src python -m benchmarks.jaxsim_grid [--quick] [--devices N]``
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import time

import numpy as np

try:
    from benchmarks._provenance import obs_scope as _obs_scope
    from benchmarks._provenance import provenance
except ImportError:       # run as a loose script from benchmarks/
    from _provenance import obs_scope as _obs_scope
    from _provenance import provenance

PARITY_KEYS = ("accuracy", "sla_violations", "reward", "response_intervals",
               "wait_intervals", "exec_intervals", "energy_mwhr", "fairness",
               "cost_per_container", "layer_fraction", "tasks_completed")

#: hard ceiling on the warm-path cost of ``telemetry="interval"`` vs
#: ``"summary"`` on the 8-trace grid (interleaved min-of-N; the static
#: grid writes one 18-column row per interval, measured ~0.5%)
MAX_TELEMETRY_OVERHEAD = 0.05


def grid_cells(n: int):
    """First ``n`` cells of the canonical (λ × seed) benchmark grid."""
    lams, seeds = (2.0, 4.0, 6.0, 8.0), (0, 1, 2, 3, 4, 5, 6, 7,
                                         8, 9, 10, 11, 12, 13, 14, 15)
    return list(itertools.product(lams, seeds))[:n] if n != 8 else \
        [(l, s) for l in lams for s in (0, 1)]


def run(n_intervals=20, substeps=10, sizes=(1, 4, 8, 16, 32, 64),
        max_active=96, out_json=None, devices=None, substep_impl=None,
        telemetry="summary", profile_dir=None):
    from repro.env import jaxsim
    from repro.launch import experiments

    dec = jaxsim.make_static_decider("bestfit-rr")

    def compile_cells(cells):
        return [jaxsim.compile_trace(dec, lam=lam, seed=seed,
                                     n_intervals=n_intervals,
                                     substeps=substeps)
                for lam, seed in cells]

    out = {"policy": "bestfit-rr", "n_intervals": n_intervals,
           "substeps": substeps, "max_active": max_active,
           "provenance": provenance(substep_impl=substep_impl or
                                    os.environ.get("JAXSIM_SUBSTEP_IMPL",
                                                   "xla"),
                                    devices=devices,
                                    telemetry=telemetry)}

    # ---- parity: 8-trace acceptance grid vs per-trace EdgeSim ----------
    cells8 = grid_cells(8)
    traces8 = compile_cells(cells8)
    t0 = time.perf_counter()
    batched = jaxsim.run_grid_arrays(traces8, max_active=max_active,
                                     substep_impl=substep_impl,
                                     telemetry=telemetry)
    compile_s = time.perf_counter() - t0
    if telemetry == "interval":
        from repro.obs import get_ledger
        get_ledger().add_series("trace0", batched[0]["telemetry"]["cols"],
                                batched[0]["telemetry"]["series"])
    max_rel = 0.0
    ok = True
    for tr, b in zip(traces8, batched):
        ref = jaxsim.replay_trace_edgesim(tr)
        for k in PARITY_KEYS:
            denom = max(abs(ref[k]), 1e-12)
            max_rel = max(max_rel, abs(ref[k] - b[k]) / denom)
            if not np.isclose(ref[k], b[k], rtol=1e-4, atol=1e-9):
                ok = False
    dropped = sum(b["dropped_tasks"] for b in batched)
    out["parity"] = {"allclose_rtol1e4": ok, "max_rel_err": max_rel,
                     "dropped_tasks": dropped, "n_traces": len(traces8)}
    print(f"parity (8-trace grid): allclose={ok} "
          f"max_rel_err={max_rel:.2e} dropped={dropped}")
    assert ok and dropped == 0, "jaxsim parity failure"

    # ---- throughput scaling: batched one-call vs host loop -------------
    # interleaved min-of-N on both sides: the container CPUs are shared,
    # so back-to-back blocks see different machine windows — alternating
    # samples keeps the comparison honest, min is the capability statistic
    def measure(size, reps):
        cells = grid_cells(size)
        traces = compile_cells(cells)
        jaxsim.run_grid_arrays(traces, max_active=max_active,
                               substep_impl=substep_impl,
                               telemetry=telemetry)  # warm/compile
        tb, th = [], []
        for _ in range(reps):
            tb.append(_timed(lambda: jaxsim.run_grid_arrays(
                traces, max_active=max_active, substep_impl=substep_impl,
                telemetry=telemetry)))
            th.append(_timed(lambda: [experiments.run_trace(
                policy=jaxsim.host_policy("bestfit-rr"),
                n_intervals=n_intervals, lam=lam, seed=seed,
                substeps=substeps) for lam, seed in cells]))
        return min(tb), min(th)

    out["grids"] = {}
    for size in sizes:
        tb, th = measure(size, reps=4)
        # shared-CPU containers hit multi-second noise windows; escalate
        # the sample count (min is the capability statistic) before
        # concluding the acceptance grid missed its bar
        for reps in (8, 12):
            if size != 8 or th / tb >= 3.0:
                break
            tb2, th2 = measure(size, reps=reps)
            tb, th = min(tb, tb2), min(th, th2)
        rec = {"batched_s": tb, "batched_traces_per_sec": size / tb,
               "host_s": th, "host_traces_per_sec": size / th,
               "speedup": th / tb}
        out["grids"][str(size)] = rec
        print(f"grid {size:3d}: batched {size / tb:7.1f} tr/s  "
              f"host {size / th:6.1f} tr/s  speedup {th / tb:5.2f}x")

    g8 = out["grids"].get("8")
    if g8:
        out["speedup_8_traces"] = g8["speedup"]
        print(f"8-trace grid speedup: {g8['speedup']:.2f}x "
              f"(compile+first-call {compile_s:.1f}s, amortized across "
              f"every later grid of the same shape)")
        assert g8["speedup"] >= 3.0, \
            f"acceptance: expected >= 3x, got {g8['speedup']:.2f}x"

    # ---- telemetry overhead: the in-carry series must be ~free ---------
    def run8(tel):
        return jaxsim.run_grid_arrays(traces8, max_active=max_active,
                                      substep_impl=substep_impl,
                                      telemetry=tel)

    run8("interval")                          # warm/compile interval mode
    run8("summary")                           # warm (cache hit)
    t_sum, t_int = [], []
    for _ in range(8):                        # interleaved: shared CPUs
        t_sum.append(_timed(lambda: run8("summary")))
        t_int.append(_timed(lambda: run8("interval")))
    overhead = min(t_int) / min(t_sum) - 1.0
    out["telemetry"] = {"mode": telemetry,
                        "summary_s": min(t_sum), "interval_s": min(t_int),
                        "overhead_8_traces": overhead,
                        "max_overhead": MAX_TELEMETRY_OVERHEAD}
    print(f"telemetry overhead (8-trace grid): {overhead * 100:+.1f}% "
          f"(summary {min(t_sum):.3f}s, interval {min(t_int):.3f}s)")
    assert overhead <= MAX_TELEMETRY_OVERHEAD, \
        f"telemetry overhead ceiling: expected <= " \
        f"{MAX_TELEMETRY_OVERHEAD:.0%}, got {overhead:.1%}"

    # ---- device scaling: shard_map mesh vs single-device whole-grid ----
    if devices:
        d = int(devices)
        traces = compile_cells(grid_cells(2 * d))
        nt = len(traces)

        def single():
            return jaxsim.run_grid_arrays(traces, max_active=max_active,
                                          threads=1,
                                          substep_impl=substep_impl)

        def sharded():
            return jaxsim.run_grid_arrays(traces, max_active=max_active,
                                          devices=d,
                                          substep_impl=substep_impl)

        base, shd = single(), sharded()      # warm/compile both paths
        for i, (b, s) in enumerate(zip(base, shd)):
            for k in PARITY_KEYS:
                assert np.isclose(b[k], s[k], rtol=1e-4, atol=1e-9), \
                    f"sharded row {i} {k}: single={b[k]!r} sharded={s[k]!r}"
        t1 = min(_timed(single) for _ in range(4))
        td = min(_timed(sharded) for _ in range(4))
        rec = {"devices": d, "n_traces": nt,
               "single_device_s": t1, "sharded_s": td,
               "single_device_traces_per_sec": nt / t1,
               "sharded_traces_per_sec": nt / td,
               "speedup_vs_single_device": t1 / td}
        out["devices_scaling"] = rec
        print(f"devices {d}: sharded {nt / td:7.1f} tr/s  "
              f"single-dev {nt / t1:7.1f} tr/s  speedup {t1 / td:5.2f}x")
        cores = os.cpu_count() or 1
        if cores >= d:
            assert t1 / td >= 3.0, \
                f"device scaling: expected >= 3x on {cores} cores, " \
                f"got {t1 / td:.2f}x"
        else:
            print(f"note: {cores} host cores < {d} forced devices — "
                  "timeshared cores, speedup informational only")

    if profile_dir:
        from repro.obs import get_ledger
        with get_ledger().profile(profile_dir):
            jaxsim.run_grid_arrays(traces8, max_active=max_active,
                                   substep_impl=substep_impl,
                                   telemetry=telemetry)

    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(out, f, indent=1)
    return out


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (parity + 1/8-trace grids)")
    ap.add_argument("--devices", type=int, default=None,
                    help="measure shard_map grid dispatch over N devices "
                         "(forces N host devices on CPU)")
    ap.add_argument("--substep-impl", default=None,
                    choices=("xla", "pallas", "ref"),
                    help="substep physics implementation")
    ap.add_argument("--devices-only", action="store_true",
                    help="parity + device scaling only; skip the "
                         "host-loop throughput grids (the xla leg owns "
                         "that floor)")
    ap.add_argument("--telemetry", default="summary",
                    choices=("summary", "interval"),
                    help="run the measured grids with the in-carry "
                         "interval telemetry series on")
    ap.add_argument("--profile-dir", default=None,
                    help="also capture a jax.profiler trace of one warm "
                         "grid call under this directory")
    ap.add_argument("--out", default="benchmarks/results/jaxsim_grid.json")
    args = ap.parse_args()
    if args.devices and args.devices > 1:
        # must land before the first jax import (run() imports lazily)
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count="
                + str(args.devices)).strip()
    kw = dict(out_json=args.out, devices=args.devices,
              substep_impl=args.substep_impl, telemetry=args.telemetry,
              profile_dir=args.profile_dir)
    with _obs_scope("jaxsim_grid", telemetry=args.telemetry,
                    devices=args.devices):
        if args.devices_only:
            run(sizes=(), **kw)
        elif args.quick:
            # acceptance-shaped grid, fewer sizes (compile dominates
            # CI time)
            run(sizes=(1, 8), **kw)
        else:
            run(**kw)


if __name__ == "__main__":
    main()
