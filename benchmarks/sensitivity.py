"""Sensitivity studies: λ (Fig. 9), α/β (Fig. 10), constrained environments
(A.3), single-app workloads (A.4), edge-vs-cloud (A.5)."""
from __future__ import annotations

import argparse
import json
import os

try:
    from benchmarks._provenance import provenance
except ImportError:       # run as a loose script from benchmarks/
    from _provenance import provenance

import numpy as np


def sweep_lambda(lams=(2, 6, 12, 24), n_intervals=40, substeps=8, seed=0):
    from repro.launch.experiments import run_grid
    keys = ("reward", "sla_violations", "accuracy", "response_intervals",
            "energy_mwhr", "layer_fraction")
    records = run_grid(("splitplace", "layer+gobi", "semantic+gobi", "mc"),
                       seeds=(seed,), lams=lams, n_intervals=n_intervals,
                       substeps=substeps, pretrain_intervals=100,
                       pretrain_lam=6.0)
    out = {}
    for rec in records:
        out.setdefault(str(rec["lam"]), {})[rec["policy"]] = \
            {k: rec[k] for k in keys}
    for lam, row in out.items():
        print(f"lam={lam}: " + " ".join(
            f"{p}:rw={row[p]['reward']:.2f}/v={row[p]['sla_violations']:.2f}"
            for p in row))
    return out


def sweep_lambda_avg(lams=(2, 6, 12, 24), seeds=(0, 1, 2), n_intervals=40,
                     substeps=8):
    """Seed-averaged λ sweep (mean ± std over 3 seeds) for the static
    BestFit policies.  Uses the batched jitted backend when available —
    each policy's whole (seed × λ) grid is one compiled vmapped call —
    and falls back to looping the host simulator otherwise."""
    from repro.launch.experiments import aggregate, run_grid_batched
    policies = ("mc", "bestfit-rr", "bestfit-threshold")
    records = []
    for pol in policies:
        try:
            records += run_grid_batched(pol, seeds=seeds, lams=lams,
                                        n_intervals=n_intervals,
                                        substeps=substeps)
        except Exception as e:                       # pragma: no cover
            print(f"batched backend unavailable ({e!r}); host fallback")
            from repro.env.jaxsim import host_policy
            from repro.launch.experiments import _record, run_trace
            for lam in lams:
                for seed in seeds:
                    r = run_trace(policy=host_policy(pol),
                                  n_intervals=n_intervals, lam=lam,
                                  seed=seed, substeps=substeps)
                    records.append(_record(pol, seed, lam, r))
    agg = aggregate(records, by=("policy", "lam"))
    out = {}
    for (pol, lam), row in agg.items():
        out.setdefault(pol, {})[str(lam)] = row
    for pol, rows in out.items():
        for lam, row in rows.items():
            print(f"{pol:18s} lam={lam:>4s}: "
                  f"reward={row['reward']:.3f}±{row['reward_std']:.3f} "
                  f"viol={row['sla_violations']:.2f} "
                  f"(n={row['n_runs']})")
    return out


def sweep_alpha_lambda(alphas=(0.0, 0.5, 1.0), lams=(2, 6, 12),
                       seeds=(0, 1, 2), n_intervals=30, substeps=8,
                       pretrain_intervals=60, pretrain_substeps=8,
                       train_hp_tail=(4, 8, 4)):
    """α×λ cross sweep of the eq.-10 trade-off (β = 1 − α) on the
    batched jitted backend: every (α) runs its whole (seed × λ) grid as
    one compiled ``mode="train"`` splitplace call — the carried DASO
    finetuning consumes the swept α/β through ``train_hp`` — and rows
    report the 3-seed mean ± std.  ``train_hp_tail`` is (train_steps,
    place_min, train_min); the lowered cold-start gates make the swept α
    reach the deployed placements within the horizon."""
    from repro.launch.experiments import (aggregate, pretrain,
                                          run_grid_batched)
    pre = pretrain(pretrain_intervals, lam=6.0, seed=7,
                   substeps=pretrain_substeps)
    keys = ("reward", "reward_std", "sla_violations", "accuracy",
            "response_intervals", "energy_mwhr", "n_runs")
    out = {}
    for alpha in alphas:
        train_hp = (float(alpha), float(1.0 - alpha)) + tuple(train_hp_tail)
        records = run_grid_batched(
            "splitplace", seeds=seeds, lams=lams, n_intervals=n_intervals,
            substeps=substeps, pretrain_state=pre, mode="train",
            train_hp=train_hp)
        agg = aggregate(records, by=("lam",))
        out[str(alpha)] = {str(lam): {k: row[k] for k in keys}
                           for lam, row in agg.items()}
        for lam, row in sorted(agg.items()):
            print(f"alpha={alpha:g} lam={lam:>4g}: "
                  f"reward={row['reward']:.3f}±{row['reward_std']:.3f} "
                  f"viol={row['sla_violations']:.2f} "
                  f"energy={row['energy_mwhr']:.4f} (n={row['n_runs']})")
    return out


def sweep_alpha(alphas=(0.0, 0.25, 0.5, 0.75, 1.0), n_intervals=30,
                substeps=8, seed=0):
    """α/β trade-off of eq. 10 (β = 1 − α) for the DASO placer."""
    from repro.core.splitplace import (MABDecider, Policy, SurrogatePlacer,
                                       pretrain_mab)
    from repro.env.cluster import make_cluster
    from repro.launch.experiments import run_trace
    state, _ = pretrain_mab(n_intervals=80, substeps=substeps, seed=7)
    n_workers = make_cluster().n
    out = {}
    for alpha in alphas:
        # custom α/β: pass a manually built policy through the runner
        pol = Policy("M+D", MABDecider(seed=seed, train=False, state=state),
                     SurrogatePlacer(n_workers, True, seed,
                                     alpha=alpha, beta=1 - alpha))
        s = run_trace(policy=pol, n_intervals=n_intervals, lam=6.0,
                      seed=seed, substeps=substeps)
        out[str(alpha)] = {k: v for k, v in s.items()
                           if isinstance(v, (int, float))
                           and not isinstance(v, bool)}
        print(f"alpha={alpha}: reward={s['reward']:.3f} "
              f"energy={s['energy_mwhr']:.4f} resp={s['response_intervals']:.2f}")
    return out


def constrained_envs(n_intervals=30, substeps=8, seed=0):
    """A.3: compute / network / memory constrained clusters (halved)."""
    from repro.core.splitplace import pretrain_mab
    from repro.env.cluster import make_cluster
    from repro.launch.experiments import run_grid
    state, _ = pretrain_mab(n_intervals=80, substeps=substeps, seed=7)
    envs = {
        "normal": {},
        "compute": dict(compute_scale=0.5),
        "network": dict(net_scale=0.5),
        "memory": dict(ram_scale=0.5),
    }
    keys = ("reward", "sla_violations", "accuracy", "response_intervals")
    out = {}
    for name, kw in envs.items():
        records = run_grid(("splitplace", "gillis", "mc"), seeds=(seed,),
                           lams=(6.0,), n_intervals=n_intervals,
                           substeps=substeps, mab_state=state,
                           cluster_factory=lambda kw=kw: make_cluster(**kw))
        out[name] = {rec["policy"]: {k: rec[k] for k in keys}
                     for rec in records}
        print(f"{name:8s}: " + " ".join(
            f"{p}:rw={out[name][p]['reward']:.2f}" for p in out[name]))
    return out


def single_app(n_intervals=30, substeps=8, seed=0):
    """A.4: MNIST-only / FashionMNIST-only / CIFAR100-only workloads."""
    from repro.core.splitplace import pretrain_mab
    from repro.launch.experiments import run_grid
    state, _ = pretrain_mab(n_intervals=80, substeps=substeps, seed=7)
    keys = ("reward", "sla_violations", "accuracy", "response_intervals")
    out = {}
    for app, name in enumerate(("mnist", "fashionmnist", "cifar100")):
        rec = run_grid(("splitplace",), seeds=(seed,), lams=(6.0,),
                       n_intervals=n_intervals, substeps=substeps,
                       mab_state=state, apps=[app])[0]
        out[name] = {k: rec[k] for k in keys}
        print(f"{name:13s}: reward={rec['reward']:.3f} "
              f"viol={rec['sla_violations']:.2f} acc={rec['accuracy']:.3f}")
    return out


def edge_vs_cloud(n_intervals=30, substeps=8, seed=0):
    """A.5: multi-hop 'cloud' workers (5x base latency, 0.3x bandwidth) vs
    the edge LAN — monolithic execution on cloud vs SplitPlace on edge."""
    from repro.core.splitplace import pretrain_mab, run_experiment
    from repro.env.cluster import make_cluster
    state, _ = pretrain_mab(n_intervals=80, substeps=substeps, seed=7)
    edge = run_experiment("splitplace", n_intervals=n_intervals, lam=6.0,
                          seed=seed, mab_state=state, substeps=substeps)
    cloud = run_experiment("mc", n_intervals=n_intervals, lam=6.0, seed=seed,
                           substeps=substeps,
                           cluster=make_cluster(net_scale=0.3))
    out = {"edge_splitplace": {k: edge[k] for k in
                               ("reward", "sla_violations",
                                "response_intervals")},
           "cloud_monolithic": {k: cloud[k] for k in
                                ("reward", "sla_violations",
                                 "response_intervals")}}
    print(f"edge:  viol={edge['sla_violations']:.2f} resp={edge['response_intervals']:.2f}")
    print(f"cloud: viol={cloud['sla_violations']:.2f} resp={cloud['response_intervals']:.2f}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", default="lambda",
                    choices=["lambda", "lambda_avg", "alpha",
                             "alpha_lambda", "constrained", "apps",
                             "cloud", "all"])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized parameters (fewer α/λ points, shorter "
                         "horizons) — currently honoured by alpha_lambda")
    ap.add_argument("--out", default="benchmarks/results/sensitivity.json")
    args = ap.parse_args()
    alpha_lambda = (lambda: sweep_alpha_lambda(
        alphas=(0.0, 1.0), lams=(3, 8), seeds=(0, 1, 2), n_intervals=10,
        substeps=4, pretrain_intervals=8, pretrain_substeps=4,
        train_hp_tail=(2, 4, 2))) if args.quick else sweep_alpha_lambda
    fns = {"lambda": sweep_lambda, "lambda_avg": sweep_lambda_avg,
           "alpha": sweep_alpha, "alpha_lambda": alpha_lambda,
           "constrained": constrained_envs, "apps": single_app,
           "cloud": edge_vs_cloud}
    res = {}
    todo = list(fns) if args.sweep == "all" else [args.sweep]
    for name in todo:
        print(f"== {name}")
        res[name] = fns[name]()
    res["provenance"] = provenance()
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
