"""Sensitivity studies: λ (Fig. 9), α/β (Fig. 10), constrained environments
(A.3), single-app workloads (A.4), edge-vs-cloud (A.5)."""
from __future__ import annotations

import argparse
import json
import os

import numpy as np


def sweep_lambda(lams=(2, 6, 12, 24), n_intervals=40, substeps=8, seed=0):
    from repro.core.splitplace import pretrain_mab, run_experiment
    state, _ = pretrain_mab(n_intervals=100, substeps=substeps, seed=7)
    out = {}
    for lam in lams:
        row = {}
        for pol in ("splitplace", "layer+gobi", "semantic+gobi", "mc"):
            ms = state if pol == "splitplace" else None
            r = run_experiment(pol, n_intervals=n_intervals, lam=lam,
                               seed=seed, mab_state=ms, substeps=substeps)
            row[pol] = {k: r[k] for k in
                        ("reward", "sla_violations", "accuracy",
                         "response_intervals", "energy_mwhr",
                         "layer_fraction")}
        out[str(lam)] = row
        print(f"lam={lam}: " + " ".join(
            f"{p}:rw={row[p]['reward']:.2f}/v={row[p]['sla_violations']:.2f}"
            for p in row))
    return out


def sweep_alpha(alphas=(0.0, 0.25, 0.5, 0.75, 1.0), n_intervals=30,
                substeps=8, seed=0):
    """α/β trade-off of eq. 10 (β = 1 − α) for the DASO placer."""
    from repro.core.splitplace import (MABDecider, Policy, SurrogatePlacer,
                                       pretrain_mab)
    from repro.core.splitplace import run_experiment
    state, _ = pretrain_mab(n_intervals=80, substeps=substeps, seed=7)
    out = {}
    for alpha in alphas:
        import repro.core.splitplace as sp

        # run with custom alpha by constructing the policy manually
        from repro.env.metrics import MetricsAccumulator
        from repro.env.simulator import EdgeSim
        sim = EdgeSim(lam=6.0, seed=seed, substeps=substeps)
        pol = Policy("M+D", MABDecider(seed=seed, train=False, state=state),
                     SurrogatePlacer(sim.cluster.n, True, seed,
                                     alpha=alpha, beta=1 - alpha))
        acc = MetricsAccumulator()
        for t in range(n_intervals):
            tasks = sim.new_interval_tasks()
            sim.admit(tasks, pol.decider.decide(tasks))
            sim.apply_placement(pol.placer.place(sim))
            stats = sim.advance()
            pol.decider.feedback(stats.finished)
            pol.placer.feedback(pol.decider.interval_reward(stats.finished),
                                stats, sim)
            acc.update(stats)
        s = acc.summary()
        out[str(alpha)] = s
        print(f"alpha={alpha}: reward={s['reward']:.3f} "
              f"energy={s['energy_mwhr']:.4f} resp={s['response_intervals']:.2f}")
    return out


def constrained_envs(n_intervals=30, substeps=8, seed=0):
    """A.3: compute / network / memory constrained clusters (halved)."""
    from repro.core.splitplace import pretrain_mab, run_experiment
    from repro.env.cluster import make_cluster
    state, _ = pretrain_mab(n_intervals=80, substeps=substeps, seed=7)
    envs = {
        "normal": {},
        "compute": dict(compute_scale=0.5),
        "network": dict(net_scale=0.5),
        "memory": dict(ram_scale=0.5),
    }
    out = {}
    for name, kw in envs.items():
        row = {}
        for pol in ("splitplace", "gillis", "mc"):
            ms = state if pol == "splitplace" else None
            r = run_experiment(pol, n_intervals=n_intervals, lam=6.0,
                               seed=seed, mab_state=ms, substeps=substeps,
                               cluster=make_cluster(**kw))
            row[pol] = {k: r[k] for k in
                        ("reward", "sla_violations", "accuracy",
                         "response_intervals")}
        out[name] = row
        print(f"{name:8s}: " + " ".join(
            f"{p}:rw={row[p]['reward']:.2f}" for p in row))
    return out


def single_app(n_intervals=30, substeps=8, seed=0):
    """A.4: MNIST-only / FashionMNIST-only / CIFAR100-only workloads."""
    from repro.core.splitplace import pretrain_mab, run_experiment
    state, _ = pretrain_mab(n_intervals=80, substeps=substeps, seed=7)
    out = {}
    for app, name in enumerate(("mnist", "fashionmnist", "cifar100")):
        r = run_experiment("splitplace", n_intervals=n_intervals, lam=6.0,
                           seed=seed, mab_state=state, substeps=substeps,
                           apps=[app])
        out[name] = {k: r[k] for k in ("reward", "sla_violations",
                                       "accuracy", "response_intervals")}
        print(f"{name:13s}: reward={r['reward']:.3f} "
              f"viol={r['sla_violations']:.2f} acc={r['accuracy']:.3f}")
    return out


def edge_vs_cloud(n_intervals=30, substeps=8, seed=0):
    """A.5: multi-hop 'cloud' workers (5x base latency, 0.3x bandwidth) vs
    the edge LAN — monolithic execution on cloud vs SplitPlace on edge."""
    from repro.core.splitplace import pretrain_mab, run_experiment
    from repro.env.cluster import make_cluster
    state, _ = pretrain_mab(n_intervals=80, substeps=substeps, seed=7)
    edge = run_experiment("splitplace", n_intervals=n_intervals, lam=6.0,
                          seed=seed, mab_state=state, substeps=substeps)
    cloud = run_experiment("mc", n_intervals=n_intervals, lam=6.0, seed=seed,
                           substeps=substeps,
                           cluster=make_cluster(net_scale=0.3))
    out = {"edge_splitplace": {k: edge[k] for k in
                               ("reward", "sla_violations",
                                "response_intervals")},
           "cloud_monolithic": {k: cloud[k] for k in
                                ("reward", "sla_violations",
                                 "response_intervals")}}
    print(f"edge:  viol={edge['sla_violations']:.2f} resp={edge['response_intervals']:.2f}")
    print(f"cloud: viol={cloud['sla_violations']:.2f} resp={cloud['response_intervals']:.2f}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", default="lambda",
                    choices=["lambda", "alpha", "constrained", "apps",
                             "cloud", "all"])
    ap.add_argument("--out", default="benchmarks/results/sensitivity.json")
    args = ap.parse_args()
    fns = {"lambda": sweep_lambda, "alpha": sweep_alpha,
           "constrained": constrained_envs, "apps": single_app,
           "cloud": edge_vs_cloud}
    res = {}
    todo = list(fns) if args.sweep == "all" else [args.sweep]
    for name in todo:
        print(f"== {name}")
        res[name] = fns[name]()
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
