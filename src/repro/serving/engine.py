"""SLA-aware serving engine: SplitPlace's MAB policy driving real plan
selection over batched requests (the TPU-native integration, DESIGN §2.2).

Per request batch:
  1. context = deadline vs EMA estimate of the layer-pipeline latency
     (eq. 2 semantics, measured wall-clock here);
  2. the MAB (UCB at serve time) picks layer_pipeline or semantic_branch;
  3. the plan executes (really — pipeline_forward / branch_forward);
  4. reward couples deadline satisfaction with fidelity (agreement of the
     plan's argmax tokens vs the monolithic forward), eqs. 3–5.

On hardware the two plans map to mesh-slice pipelining vs branch-parallel
execution; on CPU the latency separation is real (branch_forward does
~1/B of the FLOPs per branch).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import daso as daso_mod
from repro.core import mab as mab_mod
from repro.models.model import forward
from repro.serving.plans import (LAYER_PLAN, SEMANTIC_PLAN, PlanSpec,
                                 branch_forward, pipeline_forward)


@dataclasses.dataclass
class Request:
    tokens: np.ndarray          # (b, s)
    deadline_s: float
    app: int = 0


@dataclasses.dataclass
class ServeResult:
    plan: int
    latency_s: float
    fidelity: float             # argmax agreement with monolithic forward
    met_deadline: bool
    reward: float


class SplitPlaceEngine:
    def __init__(self, params, cfg, num_stages=2, num_branches=2,
                 phi=0.9, gamma=0.3, ucb_c=0.5, seed=0, num_slices=4):
        self.params = params
        self.cfg = cfg
        self.layer_plan = PlanSpec(LAYER_PLAN, num_stages=num_stages)
        self.sem_plan = PlanSpec(SEMANTIC_PLAN, num_branches=num_branches)
        self.state = mab_mod.init_state(num_apps=1)
        self.phi, self.gamma, self.ucb_c = phi, gamma, ucb_c
        from repro.serving.plans import optimal_stage_bounds
        self._stage_bounds = optimal_stage_bounds(cfg, seq=256, batch=1,
                                                  num_stages=num_stages)
        self._pipe = jax.jit(lambda p, b: pipeline_forward(
            p, b, cfg, num_stages, bounds=self._stage_bounds))
        self._branch = jax.jit(lambda p, b: branch_forward(
            p, b, cfg, num_branches))
        self._mono = jax.jit(lambda p, b: forward(p, b, cfg)[0])
        # DASO fragment->mesh-slice placement (the paper's placement
        # sub-problem): per-slice queue depth is the state; fragments are
        # pipeline stages or semantic branches
        self.num_slices = num_slices
        max_frag = max(num_stages, num_branches)
        self._daso_cfg = daso_mod.DASOConfig(
            num_workers=num_slices, max_containers=max_frag,
            state_features=1, hidden=32, depth=2, place_iters=25,
            lr_place=0.2)
        self._theta, self._daso_opt = daso_mod.make_trainer(
            self._daso_cfg, jax.random.PRNGKey(seed))
        self.slice_load = np.zeros(num_slices)
        self._replay = []

    def place_fragments(self, plan: int):
        """DASO placement of the plan's fragments onto mesh slices given
        current per-slice queue depths; returns (assignment, queue_cost)."""
        n = (self.layer_plan.num_stages if plan == LAYER_PLAN
             else self.sem_plan.num_branches)
        C = self._daso_cfg.max_containers
        mask = np.zeros(C, np.float32)
        mask[:n] = 1.0
        decisions = np.full(C, plan, np.int32)
        logits = np.zeros((C, self.num_slices), np.float32)
        # warm start: least-loaded slices
        order = np.argsort(self.slice_load)
        for i in range(n):
            logits[i, order[i % self.num_slices]] = 2.0
        state = jnp.asarray(self.slice_load[:, None] / 4.0, jnp.float32)
        if len(self._replay) >= 16:
            p_opt, _, _ = daso_mod.optimize_placement(
                self._daso_cfg, self._theta, state, jnp.asarray(logits),
                jnp.asarray(decisions), jnp.asarray(mask))
        else:
            p_opt = jnp.asarray(logits)
        assign = np.asarray(daso_mod.placement_to_assignment(
            p_opt, jnp.asarray(mask)))[:n]
        if plan == LAYER_PLAN:
            # sequential stages: queue cost = sum of per-stage waits
            qcost = float(sum(self.slice_load[a] for a in assign))
        else:
            # parallel branches: straggler = max wait
            qcost = float(max(self.slice_load[a] for a in assign))
        for a in assign:
            self.slice_load[a] += 1.0
        self.slice_load *= 0.8                     # queues drain
        x = np.asarray(daso_mod.pack_input(
            self._daso_cfg, state, p_opt, jnp.asarray(decisions),
            jnp.asarray(mask)))
        return assign, qcost, x

    def _daso_feedback(self, x, reward):
        self._replay.append((x, reward))
        if len(self._replay) >= 16 and len(self._replay) % 4 == 0:
            xs = jnp.asarray(np.stack([r[0] for r in self._replay[-64:]]))
            ys = jnp.asarray(np.array([r[1] for r in self._replay[-64:]],
                                      np.float32))
            for _ in range(2):
                self._theta, self._daso_opt, _ = daso_mod.train_epoch(
                    self._daso_cfg, self._theta, self._daso_opt, xs, ys)

    def warmup(self, batch):
        b = {"tokens": jnp.asarray(batch)}
        self._pipe(self.params, b).block_until_ready()
        self._branch(self.params, b).block_until_ready()
        self._mono(self.params, b).block_until_ready()

    def _run(self, plan_kind: int, batch) -> tuple:
        fn = self._pipe if plan_kind == LAYER_PLAN else self._branch
        t0 = time.perf_counter()
        logits = fn(self.params, batch)
        logits.block_until_ready()
        wall = time.perf_counter() - t0
        if plan_kind != LAYER_PLAN:
            # branches run on disjoint mesh slices in parallel on hardware;
            # this CPU executes them serially, so wall time over-counts by
            # the branch count
            wall /= self.sem_plan.num_branches
        return logits, wall

    def serve(self, req: Request) -> ServeResult:
        batch = {"tokens": jnp.asarray(req.tokens)}
        d, ctx = mab_mod.decide_ucb(self.state, jnp.float32(req.deadline_s),
                                    req.app, self.ucb_c)
        plan = int(d)                     # 0=LAYER(pipeline) 1=SEMANTIC(branch)
        assign, qcost, daso_x = self.place_fragments(plan)
        logits, latency = self._run(plan, batch)
        latency = latency * (1.0 + 0.25 * qcost)   # queueing on busy slices
        ref = self._mono(self.params, batch)
        fid = float((jnp.argmax(logits, -1) == jnp.argmax(ref, -1)).mean())
        met = latency <= req.deadline_s
        reward = 0.5 * (float(met) + fid)
        # Algorithm-1 bookkeeping (single leaving task)
        self.state = mab_mod.end_of_interval(
            self.state,
            jnp.array([req.app], jnp.int32),
            jnp.array([req.deadline_s], jnp.float32),
            jnp.array([latency], jnp.float32),
            jnp.array([fid], jnp.float32),
            jnp.array([plan], jnp.int32),
            self.phi, self.gamma)
        self._daso_feedback(daso_x, reward)
        return ServeResult(plan, latency, fid, met, reward)

    def serve_many(self, reqs: List[Request]) -> List[ServeResult]:
        return [self.serve(r) for r in reqs]
