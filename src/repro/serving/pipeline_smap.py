"""Layer-split execution as a REAL SPMD pipeline.

``shard_map`` over a ``stage`` mesh axis: each device group holds only its
own contiguous slice of the layer stack (the stacked scan-body params are
sharded on their leading layer dim), activations move stage-to-stage with
``jax.lax.ppermute`` (ICI neighbor hops on hardware), and microbatches
flow through a GPipe schedule of M + S − 1 ticks.

This is the paper's layer-wise split realized as a distributed program —
fragment ≙ stage, activation forwarding ≙ collective-permute — rather
than the stage-structured-but-local ``pipeline_forward``.  Supports the
dense/uniform-pattern architectures (every layer the same block kind).

Validated against the monolithic ``forward`` on a 4-device CPU mesh in
``tests/test_pipeline_smap.py`` (subprocess, 4 forced host devices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M

# jax-version compat: shard_map moved to the jax namespace (and pvary
# appeared) after 0.4.x; fall back to the experimental module / identity
if hasattr(jax, "shard_map"):
    _smap = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _smap
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def _uniform_kind(cfg):
    kinds = set(cfg.layer_kinds)
    if len(kinds) != 1:
        raise ValueError(f"shard_map pipeline needs a uniform layer "
                         f"pattern, got {kinds}")
    return next(iter(kinds))


def pipeline_shard_map(params, batch, cfg, mesh: Mesh, num_microbatches: int,
                       stage_axis: str = "stage"):
    """Full-sequence forward through an S-stage, M-microbatch pipeline.

    params: standard model params (body stacked over layers; the layer dim
    must divide the stage axis size).  batch: {"tokens": (b, s)} with b
    divisible by num_microbatches.  Returns logits identical to
    ``forward`` (up to float reassociation).
    """
    kind = _uniform_kind(cfg)
    S = mesh.shape[stage_axis]
    tokens = batch["tokens"]
    b, seq = tokens.shape
    Mb = num_microbatches
    assert b % Mb == 0, (b, Mb)
    prefix, (pattern, periods), suffix = cfg.scan_segments
    assert not prefix and not suffix and len(pattern) == 1
    assert periods % S == 0, (periods, S)

    ctx = M._make_ctx({"tokens": tokens[: b // Mb]}, cfg, None,
                      cache_len=seq)

    # embed on every device (replicated), split into microbatches
    x = M.embed_tokens(params, batch, cfg, M._make_ctx(batch, cfg, None,
                                                       cache_len=seq)["positions"])
    x_mb = x.reshape(Mb, b // Mb, seq, cfg.d_model)

    body = params["body"]            # stacked (periods, ...)
    per_stage = periods // S

    def stage_fn(local_body, x_mb_local):
        # local_body: (per_stage, ...) this stage's layers
        # x_mb_local: (Mb, mb, s, d) — full microbatch set (replicated in)
        sidx = jax.lax.axis_index(stage_axis)
        T = Mb + S - 1
        mb_shape = x_mb_local.shape[1:]

        def run_stage(act):
            out = act
            for i in range(per_stage):
                layer = jax.tree.map(lambda a: a[i], local_body)
                out, _, _ = M.apply_block(kind, layer[f"b0"] if isinstance(
                    layer, dict) and "b0" in layer else layer, out, ctx, cfg)
            return out

        right_perm = [(i, i + 1) for i in range(S - 1)]

        def tick(carry, t):
            inbox, outputs = carry
            # stage 0 injects microbatch t (zeros once drained)
            mb_t = jax.lax.dynamic_index_in_dim(
                x_mb_local, jnp.clip(t, 0, Mb - 1), 0, keepdims=False)
            inject = jnp.where(t < Mb, mb_t, jnp.zeros(mb_shape, mb_t.dtype))
            act = jnp.where(sidx == 0, inject, inbox)
            out = run_stage(act)
            # last stage writes its finished microbatch (t - S + 1)
            done_idx = jnp.clip(t - (S - 1), 0, Mb - 1)
            write = jnp.logical_and(sidx == S - 1, t >= S - 1)
            cur = jax.lax.dynamic_index_in_dim(outputs, done_idx, 0,
                                               keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(write, out, cur), done_idx, 0)
            # forward activations one stage to the right
            inbox = jax.lax.ppermute(out, stage_axis, right_perm)
            return (inbox, outputs), None

        inbox0 = _pvary(jnp.zeros(mb_shape, x_mb_local.dtype), (stage_axis,))
        outputs0 = _pvary(jnp.zeros_like(x_mb_local), (stage_axis,))
        (inbox, outputs), _ = jax.lax.scan(tick, (inbox0, outputs0),
                                           jnp.arange(T))
        # every stage returns its buffer; only the last stage's is real
        return outputs[None]

    body_specs = jax.tree.map(lambda _: P(stage_axis), body)
    out = _smap(stage_fn, mesh=mesh,
                in_specs=(body_specs, P()),
                out_specs=P(stage_axis))(body, x_mb)
    x_out = out[S - 1].reshape(b, seq, cfg.d_model)
    return M.lm_head(params, x_out, cfg)
