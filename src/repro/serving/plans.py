"""Execution plans — the paper's split strategies as TPU serving plans.

* ``layer_pipeline``: the layer-split analog.  The layer stack is cut into
  S sequential stages (on hardware: one mesh sub-slice per stage,
  activations forwarded stage-to-stage over ICI).  Full fidelity, higher
  per-request latency, pipelined throughput.

* ``semantic_branch``: the semantic-split analog.  B disjoint branches,
  each using a 1/B head-group and 1/B ffn-channel slice of the weights,
  run in parallel and their logits are combined.  Reduced fidelity
  (measurably — branches share no features), lower latency.

Both are REAL executions of the same parameters (sliced views), so the
accuracy/latency trade-off the MAB consumes is measured, not assumed.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from repro.models import model as M

LAYER_PLAN, SEMANTIC_PLAN = 0, 1


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    kind: int                 # LAYER_PLAN | SEMANTIC_PLAN
    num_stages: int = 2       # pipeline stages (layer plan)
    num_branches: int = 2     # parallel branches (semantic plan)


def stage_bounds(num_layers: int, num_stages: int):
    import numpy as np
    b = np.linspace(0, num_layers, num_stages + 1).astype(int)
    return list(zip(b[:-1], b[1:]))


def optimal_stage_bounds(cfg, seq: int, batch: int, num_stages: int):
    """Gillis-DP stage boundaries from the analytic per-layer cost table
    (latency-balanced cuts instead of equal layer counts)."""
    from repro.core.partitioner import model_layer_costs, optimal_partition
    costs = model_layer_costs(cfg, seq, batch)
    cuts, _ = optimal_partition(costs, num_stages, [1.0], hop_bw=1e15,
                                exact=True)
    return list(zip(cuts[:-1], cuts[1:]))


def pipeline_forward(params, batch, cfg, num_stages: int, constrain=None,
                     bounds=None):
    """Layer-split execution: identical math to ``forward`` but structured
    as sequential stages (the per-stage boundary is where activations move
    between mesh slices on hardware).  Must equal forward() exactly for
    ANY stage boundaries; ``bounds`` defaults to equal layer counts, the
    serving engine passes Gillis-DP latency-balanced cuts."""
    ctx = M._make_ctx(batch, cfg, constrain,
                      cache_len=batch["tokens"].shape[1])
    x = M.embed_tokens(params, batch, cfg, ctx["positions"])
    kinds = cfg.layer_kinds
    blocks = _flat_blocks(params, cfg)
    for lo, hi in (bounds or stage_bounds(len(kinds), num_stages)):
        for i in range(lo, hi):
            x, _, _ = M.apply_block(kinds[i], blocks[i], x, ctx, cfg)
    return M.lm_head(params, x, cfg)


def _flat_blocks(params, cfg) -> List:
    """Per-layer params in order (prefix, unstacked body periods, suffix)."""
    prefix, (pattern, periods), suffix = cfg.scan_segments
    blocks = list(params["prefix"])
    if periods:
        for i in range(periods):
            period = jax.tree.map(lambda a: a[i], params["body"])
            for j in range(len(pattern)):
                blocks.append(period[f"b{j}"])
    blocks.extend(params["suffix"])
    return blocks


def _slice_block_params(block, cfg, branch, num_branches):
    """Head-group / channel-group slice of one block's weights."""
    def cut(arr, axis, n=num_branches, b=None):
        b = branch if b is None else b
        size = arr.shape[axis] // n
        return jax.lax.slice_in_dim(arr, b * size, (b + 1) * size, axis=axis)

    out = dict(block)
    if "attn" in block:
        a = dict(block["attn"])
        kvh = cfg.num_kv_heads
        if cfg.num_heads % num_branches == 0 and kvh % num_branches == 0:
            a["wq"] = cut(a["wq"], 1)
            a["wk"] = cut(a["wk"], 1)
            a["wv"] = cut(a["wv"], 1)
            a["wo"] = cut(a["wo"], 0)
            if "bq" in a:
                a["bq"], a["bk"], a["bv"] = (cut(a["bq"], 0), cut(a["bk"], 0),
                                             cut(a["bv"], 0))
        out["attn"] = a
    if "mlp" in block:
        m = dict(block["mlp"])
        m["w_up"] = cut(m["w_up"], 1)
        m["w_down"] = cut(m["w_down"], 0)
        if "w_gate" in m:
            m["w_gate"] = cut(m["w_gate"], 1)
        out["mlp"] = m
    return out


def branch_forward(params, batch, cfg, num_branches: int, constrain=None):
    """Semantic-split execution: B disjoint weight-slice branches run the
    whole depth in parallel; branch logits are averaged.  Approximate by
    construction (no cross-branch features) — the fidelity cost the MAB
    trades against latency."""
    ctx = M._make_ctx(batch, cfg, constrain,
                      cache_len=batch["tokens"].shape[1])
    kinds = cfg.layer_kinds
    blocks = _flat_blocks(params, cfg)

    def one_branch(branch):
        x = M.embed_tokens(params, batch, cfg, ctx["positions"])
        for kind, block in zip(kinds, blocks):
            sliced = _slice_block_params(block, cfg, branch, num_branches)
            x, _, _ = M.apply_block(kind, sliced, x, ctx, cfg)
        return M.lm_head(params, x, cfg)

    logits = [one_branch(b) for b in range(num_branches)]
    return sum(logits) / num_branches


def plan_cost_model(cfg, plan: PlanSpec, seq: int, batch: int,
                    chips_per_slice: int = 64):
    """Napkin latency model (seconds) used to seed the MAB estimates:
    layer pipeline pays sequential stages + hop latency; semantic branches
    run 1/B of the width in parallel."""
    from repro.launch.mesh import ICI_BW, PEAK_FLOPS_BF16
    flops = 2.0 * cfg.active_param_count() * seq * batch
    if plan.kind == LAYER_PLAN:
        hop_bytes = batch * seq * cfg.d_model * 2
        per_stage = flops / plan.num_stages / (chips_per_slice * PEAK_FLOPS_BF16 * 0.4)
        return plan.num_stages * per_stage + \
            (plan.num_stages - 1) * hop_bytes / ICI_BW
    per_branch = (flops / plan.num_branches) / \
        (chips_per_slice * PEAK_FLOPS_BF16 * 0.4)
    return per_branch
