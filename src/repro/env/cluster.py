"""Heterogeneous edge cluster model — the paper's Table 3 Azure fleet.

50 worker VMs (B2ms / E2asv4 / B4ms / E4asv4) + an L8sv2 broker.  Power
curves follow the SPEC-benchmark linear idle→peak model the paper cites;
costs are the Table 3 $/hr figures.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkerType:
    name: str
    cores: int
    mips: float            # per Table 3 (aggregate MIPS)
    ram_mb: float
    ram_bw: float          # MB/s
    ping_ms: float
    net_bw: float          # MB/s NIC
    disk_bw: float         # MB/s
    cost_hr: float         # USD/hr
    power_idle: float      # W (SPEC-style linear model)
    power_peak: float
    mobile: bool


WORKER_TYPES = {
    # name          cores MIPS   RAM    RAMbw ping netbw  disk   $/hr    Pidle Ppeak mobile
    "B2ms":   WorkerType("B2ms",   2, 4029, 4295,  372, 2, 1000, 13.40, 0.0944, 75, 117, True),
    "E2asv4": WorkerType("E2asv4", 2, 4019, 4172,  412, 2, 1000, 10.30, 0.1480, 71, 110, True),
    "B4ms":   WorkerType("B4ms",   4, 8102, 7962,  360, 3, 2500, 10.60, 0.1890, 83, 142, False),
    "E4asv4": WorkerType("E4asv4", 4, 7962, 7962,  476, 3, 2500, 11.64, 0.2960, 79, 131, False),
}

# 50-worker fleet (20 + 10 + 10 + 10; the paper's Table 3 lists the four
# worker SKUs for its 50-VM London deployment)
FLEET_SPEC = [("B2ms", 20), ("E2asv4", 10), ("B4ms", 10), ("E4asv4", 10)]


@dataclasses.dataclass
class Cluster:
    types: List[WorkerType]

    @property
    def n(self):
        return len(self.types)

    def mips(self):
        return np.array([t.mips for t in self.types], np.float64)

    def ram(self):
        return np.array([t.ram_mb for t in self.types], np.float64)

    def net_bw(self):
        return np.array([t.net_bw for t in self.types], np.float64)

    def disk_bw(self):
        return np.array([t.disk_bw for t in self.types], np.float64)

    def ping(self):
        return np.array([t.ping_ms for t in self.types], np.float64)

    def cost_hr(self):
        return np.array([t.cost_hr for t in self.types], np.float64)

    def power(self, util):
        """util (n,) in [0,1] -> Watts (n,)."""
        idle = np.array([t.power_idle for t in self.types])
        peak = np.array([t.power_peak for t in self.types])
        return idle + (peak - idle) * np.clip(util, 0, 1)

    def mobile_mask(self):
        return np.array([t.mobile for t in self.types], bool)


def make_cluster(fleet=FLEET_SPEC, compute_scale=1.0, ram_scale=1.0,
                 net_scale=1.0) -> Cluster:
    """Build the 50-worker fleet; scales support the paper's A.3
    compute/memory/network-constrained variants (0.5 = halved)."""
    types = []
    for name, qty in fleet:
        base = WORKER_TYPES[name]
        t = dataclasses.replace(
            base, mips=base.mips * compute_scale,
            ram_mb=base.ram_mb * ram_scale,
            net_bw=base.net_bw * net_scale)
        types.extend([t] * qty)
    return Cluster(types)
