"""Reference per-object edge simulator (pre-SoA implementation).

This is the seed repo's object-per-fragment ``EdgeSim`` kept verbatim as
``LegacyEdgeSim``: the equivalence suite (``tests/test_soa_equivalence.py``)
asserts the vectorized structure-of-arrays simulator in
``repro.env.simulator`` reproduces its traces exactly, and
``benchmarks/sim_throughput.py`` measures the speedup against it.  Do not
optimise this file — its value is being the slow-but-obvious spec.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.env.cluster import Cluster, make_cluster
from repro.env.mobility import MobilityModel
from repro.env.simulator import NIC_CAP_MB, IntervalStats
from repro.env.workload import Task, WorkloadGenerator


class LegacyEdgeSim:
    def __init__(self, cluster: Cluster = None, lam: float = 6.0,
                 seed: int = 0, interval_s: float = 300.0, substeps: int = 30,
                 apps=None, swap_slowdown: float = 0.5):
        self.cluster = cluster or make_cluster()
        self.gen = WorkloadGenerator(lam=lam, seed=seed, apps=apps)
        self.mob = MobilityModel(self.cluster.n, self.cluster.mobile_mask(),
                                 seed=seed + 1)
        self.interval_s = interval_s
        self.substeps = substeps
        self.swap_slowdown = swap_slowdown
        self.t = 0
        self.now = 0.0
        self.active: List[Task] = []
        self.waiting: List[Task] = []
        self.rng = np.random.RandomState(seed + 2)
        self._mips = self.cluster.mips()
        self._ram = self.cluster.ram()
        self._lat_mult = np.ones(self.cluster.n)
        self._bw_mult = np.ones(self.cluster.n)

    # ------------------------------------------------------------ state

    def containers(self):
        """All fragments of active tasks, in stable order."""
        out = []
        for task in self.active:
            for f in task.fragments:
                if not f.done:
                    out.append((task, f))
        return out

    @staticmethod
    def holds_ram(task, f) -> bool:
        """Layer chains spin containers up stage-by-stage (§3.2 precedence:
        a later container is scheduled only after the previous completes),
        so only the active fragment holds RAM; semantic branches and
        compressed containers are all live at once."""
        return (not task.chain) or f.idx == task.stage

    def state_features(self):
        """(n_workers, 4): cpu load, ram load, net quality, placed count."""
        n = self.cluster.n
        cpu = np.zeros(n)
        ram = np.zeros(n)
        cnt = np.zeros(n)
        for task, f in self.containers():
            if f.worker >= 0:
                cpu[f.worker] += f.instr_left / max(self._mips[f.worker], 1) / self.interval_s
                if self.holds_ram(task, f):
                    ram[f.worker] += f.ram_mb / self._ram[f.worker]
                cnt[f.worker] += 1
        return np.stack([np.clip(cpu, 0, 4) / 4.0, np.clip(ram, 0, 2) / 2.0,
                         1.0 / self._lat_mult, np.clip(cnt, 0, 8) / 8.0], -1)

    # -------------------------------------------------------- placement

    def apply_placement(self, assignment: Dict[int, int]):
        """assignment: fragment key (task_id, idx) -> worker.  Feasibility
        repair: greedy admit in order; RAM-infeasible fragments fall back
        to the least-loaded feasible worker, else the whole task waits."""
        ram_used = np.zeros(self.cluster.n)
        for task in self.active:
            ok = True
            for f in task.fragments:
                if f.done:
                    continue
                holds = self.holds_ram(task, f)
                w = assignment.get((task.id, f.idx), f.worker)
                if w < 0 or w >= self.cluster.n:
                    w = int(np.argmin(ram_used / self._ram))
                if holds and ram_used[w] + f.ram_mb > self._ram[w]:
                    # try least-loaded feasible worker
                    headroom = self._ram - ram_used
                    cand = int(np.argmax(headroom))
                    if headroom[cand] >= f.ram_mb:
                        w = cand
                    else:
                        ok = False
                        break
                f.worker = w
                if holds:
                    ram_used[w] += f.ram_mb
            if not ok:
                for f in task.fragments:
                    f.worker = -1
                task.placed = False
            else:
                task.placed = True

    # --------------------------------------------------------- dynamics

    def _runnable(self, task: Task, f) -> bool:
        if f.done or f.worker < 0 or not task.placed:
            return False
        if not task.chain:
            return True
        return f.idx == task.stage and f.transfer_left <= 0.0

    def advance(self) -> IntervalStats:
        self._lat_mult, self._bw_mult = self.mob.step()
        dt = self.interval_s / self.substeps
        n = self.cluster.n
        busy_time = np.zeros(n)
        finished: List[Task] = []
        per_worker_tasks = np.zeros(n)

        for task in self.waiting:
            task.wait_s += self.interval_s
        for task in self.active:
            if not task.placed:
                task.wait_s += self.interval_s

        for _ in range(self.substeps):
            # per-worker runnable census
            runnable = [(task, f) for task in self.active
                        for f in task.fragments if self._runnable(task, f)]
            load = np.zeros(n, int)
            ram_load = np.zeros(n)
            for task, f in runnable:
                load[f.worker] += 1
            for task in self.active:
                for f in task.fragments:
                    if not f.done and f.worker >= 0 and self.holds_ram(task, f):
                        ram_load[f.worker] += f.ram_mb
            swap = ram_load > self._ram
            busy_time += (load > 0) * dt
            # execution
            for task, f in runnable:
                rate = self._mips[f.worker] / max(load[f.worker], 1)
                if swap[f.worker]:
                    rate *= self.swap_slowdown
                f.instr_left -= rate * dt
                if f.instr_left <= 0:
                    f.done = True
                    per_worker_tasks[f.worker] += 1
                    if task.chain and f.idx < len(task.fragments) - 1:
                        nxt = task.fragments[f.idx + 1]
                        nxt.transfer_left = f.out_bytes
                    self._maybe_finish(task, finished)
            # transfers (layer chains)
            for task in self.active:
                if not (task.chain and task.placed):
                    continue
                f = task.fragments[task.stage]
                if task.stage > 0 and f.transfer_left > 0:
                    src = task.fragments[task.stage - 1].worker
                    dst = f.worker
                    bw = min(NIC_CAP_MB, self.cluster.net_bw()[src] / 100.0,
                             self.cluster.net_bw()[dst] / 100.0)
                    bw *= min(self._bw_mult[src], self._bw_mult[dst])
                    f.transfer_left -= bw * 1e6 * dt
                if task.fragments[task.stage].done and task.stage < len(task.fragments) - 1:
                    task.stage += 1
            self.now += dt

        # energy, cost
        util = busy_time / self.interval_s
        power = self.cluster.power(util)
        energy_j = float(np.sum(power * self.interval_s))
        cost = float(np.sum(self.cluster.cost_hr()) * self.interval_s / 3600.0)

        self.active = [t for t in self.active if not t.done]
        stats = IntervalStats(self.t, finished, energy_j, cost, util,
                              np.zeros(n), len(self.active),
                              len(self.waiting), per_worker_tasks)
        self.t += 1
        return stats

    def _maybe_finish(self, task: Task, finished):
        if all(f.done for f in task.fragments) and not task.done:
            task.done = True
            task.response_s = self.now - task.arrival_s
            task.accuracy = self.gen.accuracy_of(task)
            finished.append(task)

    # ---------------------------------------------------------- arrivals

    def new_interval_tasks(self) -> List[Task]:
        tasks = self.gen.arrivals(self.now) + self.waiting
        self.waiting = []
        return tasks

    def admit(self, tasks: List[Task], decisions):
        """Realize decisions; tasks join the active set (placement next)."""
        for task, d in zip(tasks, decisions):
            if task.decision < 0:
                self.gen.realize(task, int(d))
            self.active.append(task)


class LegacyBestFitPlacer:
    """The seed repo's BestFit placer, verbatim — per-object loop with a
    full score recomputation per fragment.  Kept (with the simulator
    above) so ``benchmarks/sim_throughput.py`` measures speedup against
    the true seed pipeline."""

    def place(self, sim) -> Dict:
        ram_free = sim.cluster.ram().copy()
        load = np.zeros(sim.cluster.n)
        for task, f in sim.containers():
            if f.worker >= 0:
                ram_free[f.worker] -= f.ram_mb
                load[f.worker] += 1
        ram_cap = sim.cluster.ram()
        mips = sim.cluster.mips()
        out = {}
        for task, f in sim.containers():
            if f.worker >= 0:
                out[(task.id, f.idx)] = f.worker
                continue
            feasible = ram_free >= f.ram_mb
            score = (-load + 0.3 * mips / mips.max()
                     + 0.1 * ram_free / ram_cap)
            score = np.where(feasible, score, -1e9)
            w = int(np.argmax(score))
            out[(task.id, f.idx)] = w
            ram_free[w] -= f.ram_mb
            load[w] += 1
        return out

    def feedback(self, *a, **k):
        pass
