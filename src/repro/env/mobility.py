"""SUMO-style urban-mobility model for worker network volatility (§6.1).

The paper replays SUMO ping/bandwidth traces through NetLimiter.  We model
each mobile worker as a vehicle whose distance-to-broker follows a bounded
random waypoint walk; latency grows and effective bandwidth shrinks with
distance.  Deterministic per seed so experiments are reproducible.
"""
from __future__ import annotations

import numpy as np


class MobilityModel:
    def __init__(self, n_workers: int, mobile_mask, seed: int = 0,
                 speed: float = 0.08, max_dist: float = 1.0):
        self.n = n_workers
        self.mobile = np.asarray(mobile_mask, bool)
        self.rng = np.random.RandomState(seed)
        self.dist = self.rng.uniform(0.1, 0.6, n_workers)
        self.dist[~self.mobile] = 0.15
        self.target = self.rng.uniform(0.05, max_dist, n_workers)
        self.speed = speed
        self.max_dist = max_dist

    def step(self):
        """Advance one scheduling interval; returns (lat_mult, bw_mult)."""
        move = np.clip(self.target - self.dist, -self.speed, self.speed)
        jitter = self.rng.normal(0, 0.01, self.n)
        self.dist = np.clip(self.dist + np.where(self.mobile, move + jitter, 0.0),
                            0.02, self.max_dist)
        reached = np.abs(self.target - self.dist) < 0.05
        new_targets = self.rng.uniform(0.05, self.max_dist, self.n)
        self.target = np.where(reached & self.mobile, new_targets, self.target)
        lat_mult = 1.0 + 3.0 * self.dist              # ping grows with distance
        bw_mult = 1.0 / (1.0 + 1.5 * self.dist)       # bandwidth shrinks
        return lat_mult, bw_mult
