"""Structure-of-arrays fragment store + vectorized interval kernels.

The seed simulator advanced every interval with a triple-nested Python
loop (substeps × tasks × fragments) over per-object ``Fragment``
dataclasses.  This module holds the flat-array replacement:

  * ``SoAStore`` owns all per-fragment and per-task simulation state in
    growable NumPy arrays.  ``Task``/``Fragment`` objects are adopted on
    first contact (``adopt_task``) and become thin views — their
    attribute reads/writes resolve into the arrays (see
    ``repro.env.workload``), so tests and placers that poke objects stay
    coherent with the vectorized kernels.
  * ``run_interval`` advances one scheduling interval — runnable census,
    MIPS sharing, swap slowdown, chain transfers, task completion — as a
    sequence of array kernels (``np.bincount`` census, masked
    gathers/scatters) instead of Python loops.

Bit-exactness contract: every kernel performs the *same elementwise float
operations in the same accumulation order* as the per-object reference
(``repro.env.legacy_sim.LegacyEdgeSim``), so traces match exactly, not
just approximately:

  * fragment rows are laid out task-major in admission order — the order
    the legacy loops iterate (compaction preserves it);
  * ``np.bincount(..., weights=...)`` accumulates sequentially in input
    order, matching the legacy per-worker ``+=`` loops;
  * per-fragment rate math (``mips / max(load, 1)``, swap multiply,
    ``instr -= rate * dt``) is identical elementwise;
  * ``now`` advances by repeated ``+= dt`` so finish timestamps carry the
    same accumulated rounding.

``tests/test_soa_equivalence.py`` pins this contract.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

NIC_CAP_MB = 10.0  # the paper's 10 MBps NIC ceiling

_F_FIELDS = (("task_of", np.int32), ("frag_idx", np.int32),
             ("instr_left", np.float64), ("ram_mb", np.float64),
             ("out_bytes", np.float64), ("worker", np.int32),
             ("done", bool), ("transfer_left", np.float64))
_T_FIELDS = (("task_id", np.int64), ("chain", bool), ("placed", bool),
             ("stage", np.int32), ("frag_start", np.int32),
             ("frag_count", np.int32), ("task_done", bool))


class SoAStore:
    """Flat per-fragment (F,) and per-task (T,) state arrays.

    Fragment rows are contiguous per task, task-major in admission order;
    ``frag_start[t] + i`` is fragment ``i`` of task ``t``.  Arrays are
    over-allocated (capacity doubling); only ``[:n_fragments]`` /
    ``[:n_tasks]`` are live.  Rows of finished tasks linger (masked out by
    ``task_done``/``done``) until ``compact``.
    """

    def __init__(self, frag_cap: int = 256, task_cap: int = 64):
        self.n_fragments = 0
        self.n_tasks = 0
        self.tasks: List = []          # task row -> Task object
        for name, dt in _F_FIELDS:
            setattr(self, name, np.zeros(frag_cap, dt))
        for name, dt in _T_FIELDS:
            setattr(self, name, np.zeros(task_cap, dt))

    # ------------------------------------------------------------ growth

    def _grow_frag(self, need: int):
        cap = len(self.instr_left)
        if self.n_fragments + need <= cap:
            return
        new_cap = max(cap * 2, self.n_fragments + need)
        for name, dt in _F_FIELDS:
            a = np.zeros(new_cap, dt)
            a[:self.n_fragments] = getattr(self, name)[:self.n_fragments]
            setattr(self, name, a)

    def _grow_task(self, need: int):
        cap = len(self.frag_start)
        if self.n_tasks + need <= cap:
            return
        new_cap = max(cap * 2, self.n_tasks + need)
        for name, dt in _T_FIELDS:
            a = np.zeros(new_cap, dt)
            a[:self.n_tasks] = getattr(self, name)[:self.n_tasks]
            setattr(self, name, a)

    # ---------------------------------------------------------- adoption

    def adopt_task(self, task) -> int:
        """Ingest a task + its fragments; objects become views."""
        frs = task.fragments
        self._grow_task(1)
        self._grow_frag(len(frs))
        ti = self.n_tasks
        self.task_id[ti] = task.id
        self.chain[ti] = task.chain
        self.placed[ti] = task.placed
        self.stage[ti] = task.stage
        self.task_done[ti] = task.done
        self.frag_start[ti] = self.n_fragments
        self.frag_count[ti] = len(frs)
        self.n_tasks += 1
        row = self.n_fragments
        for f in frs:
            self.task_of[row] = ti
            self.frag_idx[row] = f.idx
            self.instr_left[row] = f.instr_left
            self.ram_mb[row] = f.ram_mb
            self.out_bytes[row] = f.out_bytes
            self.worker[row] = f.worker
            self.done[row] = f.done
            self.transfer_left[row] = f.transfer_left
            f._store = self
            f._row = row
            row += 1
        self.n_fragments = row
        task._store = self
        task._trow = ti
        self.tasks.append(task)
        return ti

    def is_bound(self, task) -> bool:
        """Task and its fragment objects are views into *this* store (a
        re-``realize`` swaps in fresh unbound fragments)."""
        if task._store is not self:
            return False
        frs = task.fragments
        return (self.frag_count[task._trow] == len(frs)
                and all(f._store is self for f in frs))

    def _detach(self, task, ti):
        """Copy a task's final array state onto its objects, making them
        plain (unbound) again so they never alias reused rows."""
        fs, cnt = self.frag_start[ti], self.frag_count[ti]
        for f, row in zip(task.fragments, range(fs, fs + cnt)):
            if f._store is self and f._row == row:
                f._instr_left = float(self.instr_left[row])
                f._ram_mb = float(self.ram_mb[row])
                f._out_bytes = float(self.out_bytes[row])
                f._worker = int(self.worker[row])
                f._done = bool(self.done[row])
                f._transfer_left = float(self.transfer_left[row])
                f._store = None
        task._done = bool(self.task_done[ti])
        task._chain = bool(self.chain[ti])
        task._stage = int(self.stage[ti])
        task._placed = bool(self.placed[ti])
        task._store = None

    def unbind_task(self, task):
        """Detach a task (its rows are retired, masked by task_done)."""
        ti = task._trow
        self._detach(task, ti)
        fs, cnt = self.frag_start[ti], self.frag_count[ti]
        self.task_done[ti] = True
        self.done[fs:fs + cnt] = True
        self.tasks[ti] = None

    def compact(self):
        """Drop retired rows (finished / unbound tasks), preserving the
        relative admission order of the remainder.  Dropped tasks are
        detached first so caller-held references stay readable."""
        snap = []
        for ti, t in enumerate(self.tasks):
            if t is None or self.task_done[ti]:
                if (t is not None and t._store is self
                        and t._trow == ti):
                    self._detach(t, ti)
                continue
            fs, cnt = self.frag_start[ti], self.frag_count[ti]
            snap.append((t, {name: getattr(self, name)[fs:fs + cnt].copy()
                             for name, _ in _F_FIELDS},
                         {name: getattr(self, name)[ti]
                          for name, _ in _T_FIELDS}))
        self.n_fragments = 0
        self.n_tasks = 0
        self.tasks = []
        for t, fcols, tcols in snap:
            self._grow_task(1)
            cnt = len(fcols["frag_idx"])
            self._grow_frag(cnt)
            ti = self.n_tasks
            for name, _ in _T_FIELDS:
                getattr(self, name)[ti] = tcols[name]
            self.frag_start[ti] = self.n_fragments
            fs = self.n_fragments
            for name, _ in _F_FIELDS:
                getattr(self, name)[fs:fs + cnt] = fcols[name]
            self.task_of[fs:fs + cnt] = ti
            self.n_tasks += 1
            self.n_fragments += cnt
            t._trow = ti
            for f, row in zip(t.fragments, range(fs, fs + cnt)):
                f._row = row
            self.tasks.append(t)

    # ------------------------------------------------------------- views

    def live_slices(self):
        F, T = self.n_fragments, self.n_tasks
        return (self.task_of[:F], self.frag_idx[:F], self.instr_left[:F],
                self.ram_mb[:F], self.out_bytes[:F], self.worker[:F],
                self.done[:F], self.transfer_left[:F])


@dataclasses.dataclass
class IntervalResult:
    finished_rows: List[int]       # task rows in completion order
    finish_now: List[float]        # accumulated `now` at each completion
    busy_time: np.ndarray          # (n_workers,) seconds with >=1 runnable
    per_worker_tasks: np.ndarray   # (n_workers,) fragments completed
    now: float                     # accumulated clock after the interval


def run_interval(s: SoAStore, mips: np.ndarray, ram: np.ndarray,
                 net_bw: np.ndarray, bw_mult: np.ndarray, now: float,
                 interval_s: float, substeps: int,
                 swap_slowdown: float) -> IntervalResult:
    """Advance one scheduling interval over the store, in place."""
    n = len(mips)
    dt = interval_s / substeps
    busy_time = np.zeros(n)
    per_worker_tasks = np.zeros(n)
    finished_rows: List[int] = []
    finish_now: List[float] = []

    F, T = s.n_fragments, s.n_tasks
    (task_of, frag_idx, instr_left, ram_mb, out_bytes, worker, done,
     transfer_left) = s.live_slices()
    stage = s.stage[:T]
    frag_count_t = s.frag_count[:T]
    task_done = s.task_done[:T]
    # static per-interval masks (worker/placed/chain can't change
    # mid-interval; done can, and is re-masked each substep)
    chain_f = s.chain[:T][task_of]
    not_chain_f = ~chain_f
    placeable = (worker >= 0) & s.placed[:T][task_of]
    holdable = worker >= 0
    count_f = frag_count_t[task_of]
    undone = np.bincount(task_of[~done], minlength=T).astype(np.int64)
    chain_rows = np.nonzero(s.chain[:T] & s.placed[:T] & ~task_done)[0] \
        .astype(np.int32)
    any_chain = bool(chain_f.any())
    # scratch buffers reused across substeps
    notdone = np.empty(F, bool)
    is_stage = np.empty(F, bool)
    tle = np.empty(F, bool)
    runnable = np.empty(F, bool)
    holds = np.empty(F, bool)
    stage_f = np.empty(F, np.int32) if any_chain else None

    for _ in range(substeps):
        np.logical_not(done, out=notdone)
        if any_chain:
            np.take(stage, task_of, out=stage_f)
            np.equal(frag_idx, stage_f, out=is_stage)     # is-active-stage
            np.less_equal(transfer_left, 0.0, out=tle)
            tle &= is_stage
            # runnable: placed, not done, and — for layer chains — the
            # active stage with no inbound transfer
            np.logical_or(not_chain_f, tle, out=runnable)
            runnable &= placeable
            runnable &= notdone
            # RAM resident (§3.2 precedence: only a chain's active stage
            # is spun up; semantic/compressed fragments are all live)
            np.logical_or(not_chain_f, is_stage, out=holds)
            holds &= holdable
            holds &= notdone
        else:
            np.logical_and(placeable, notdone, out=runnable)
            np.logical_and(holdable, notdone, out=holds)
        run_w = worker[runnable]
        load = np.bincount(run_w, minlength=n)
        ram_load = np.bincount(worker[holds], weights=ram_mb[holds],
                               minlength=n)
        swap = ram_load > ram
        busy_time += (load > 0) * dt
        # -- execution: runnable containers share their worker's MIPS
        rate = mips[run_w] / np.maximum(load[run_w], 1)
        rate = np.where(swap[run_w], rate * swap_slowdown, rate)
        rows = np.nonzero(runnable)[0]
        instr_left[rows] -= rate * dt
        done_rows = rows[instr_left[rows] <= 0]
        if done_rows.size:
            done[done_rows] = True
            per_worker_tasks += np.bincount(worker[done_rows], minlength=n)
            # chain handoff: completed stage queues its activation transfer
            # onto the next fragment (rows are contiguous per task)
            t_of = task_of[done_rows]
            hand = chain_f[done_rows] & (frag_idx[done_rows]
                                         < count_f[done_rows] - 1)
            hrows = done_rows[hand]
            transfer_left[hrows + 1] = out_bytes[hrows]
            # task completion (in task-major order, like the legacy loop)
            np.subtract.at(undone, t_of, 1)
            fin = np.unique(t_of[undone[t_of] == 0])
            for ti in fin:
                if not task_done[ti]:
                    task_done[ti] = True
                    finished_rows.append(int(ti))
                    finish_now.append(now)
        # -- transfers: layer chains forward activations stage-to-stage
        if chain_rows.size:
            srow = s.frag_start[chain_rows] + stage[chain_rows]
            tmask = (stage[chain_rows] > 0) & (transfer_left[srow] > 0)
            if tmask.any():
                mrow = srow[tmask]
                src = worker[mrow - 1]
                dst = worker[mrow]
                bw = np.minimum(NIC_CAP_MB,
                                np.minimum(net_bw[src] / 100.0,
                                           net_bw[dst] / 100.0))
                bw = bw * np.minimum(bw_mult[src], bw_mult[dst])
                transfer_left[mrow] -= bw * 1e6 * dt
            adv = done[srow] & (stage[chain_rows]
                                < frag_count_t[chain_rows] - 1)
            stage[chain_rows[adv]] += 1
        now += dt

    return IntervalResult(finished_rows, finish_now, busy_time,
                          per_worker_tasks, now)


def state_features(s: SoAStore, mips: np.ndarray, ram: np.ndarray,
                   lat_mult: np.ndarray, interval_s: float) -> np.ndarray:
    """(n_workers, 4): cpu load, ram load, net quality, placed count —
    array version of the legacy per-container accumulation."""
    n = len(mips)
    F, T = s.n_fragments, s.n_tasks
    task_of = s.task_of[:F]
    worker = s.worker[:F]
    done = s.done[:F]
    live = (~done) & (worker >= 0)
    w = worker[live]
    cpu = np.bincount(
        w, weights=s.instr_left[:F][live] / np.maximum(mips[w], 1)
        / interval_s, minlength=n)
    chain_f = s.chain[:T][task_of]
    is_stage = s.frag_idx[:F] == s.stage[:T][task_of]
    holds = live & ((~chain_f) | is_stage)
    hw = worker[holds]
    ram_load = np.bincount(hw, weights=s.ram_mb[:F][holds] / ram[hw],
                           minlength=n)
    cnt = np.bincount(w, minlength=n).astype(np.float64)
    return np.stack([np.clip(cpu, 0, 4) / 4.0, np.clip(ram_load, 0, 2) / 2.0,
                     1.0 / lat_mult, np.clip(cnt, 0, 8) / 8.0], -1)
