"""Experiment-level metrics (paper §6.4, eqs. 13–16) and the
per-interval telemetry-row layout shared with the jitted backend."""
from __future__ import annotations

import numpy as np

#: layout of one per-interval telemetry row — the base columns of the
#: jitted backend's ``telemetry="interval"`` series and of
#: ``MetricsAccumulator(telemetry=True)``.  The first nine columns are
#: exactly the kernel's packed ``METRIC_COLS`` accumulator (as interval
#: deltas); the rest are per-interval extremes/occupancy the end-of-run
#: summary collapses away.  Engine-specific learning-signal columns
#: (``engine.telemetry_cols()``) append after these.
TELEMETRY_COLS = (
    "n_fin", "sum_resp", "n_viol", "sum_acc", "sum_reward", "sum_wait",
    "fin_layer", "fin_semantic", "fin_compressed",
    "n_dropped", "energy_j", "resp_min", "resp_max", "wait_min",
    "wait_max", "util_mean", "util_max", "n_active",
)

#: the percentile points both backends report (satellite of §6.4's
#: means; the streaming-service north star's rolling p50/p99 substrate)
PERCENTILE_QS = (50, 95, 99)


def series_percentiles(series, cols, qs=PERCENTILE_QS) -> dict:
    """Percentile estimates from a per-interval telemetry series.

    The series only keeps per-interval sums and extremes, so every
    finisher in interval ``t`` is represented by the interval's *mean*
    response/wait (weighted by ``n_fin``).  Binning error bound: a
    quantile (with linear interpolation) is a convex combination of
    order statistics and order statistics move at most as far as the
    largest pointwise perturbation, so replacing each sample by its
    interval mean shifts any percentile by at most the largest
    within-interval spread ``max_t(resp_max[t] − resp_min[t])`` (resp.
    wait).  That bound is returned as ``percentile_err_s`` and the
    parity tests assert |kernel − exact-host| ≤ it."""
    idx = {c: i for i, c in enumerate(cols)}
    series = np.asarray(series, np.float64)
    nfin = np.rint(series[:, idx["n_fin"]]).astype(np.int64)
    have = nfin > 0
    out = {}
    err = 0.0
    for name, s_col, mn_col, mx_col in (
            ("response", "sum_resp", "resp_min", "resp_max"),
            ("wait", "sum_wait", "wait_min", "wait_max")):
        if have.any():
            means = series[have, idx[s_col]] / nfin[have]
            vals = np.percentile(np.repeat(means, nfin[have]), qs)
            err = max(err, float(np.max(series[have, idx[mx_col]]
                                        - series[have, idx[mn_col]])))
        else:
            vals = np.zeros(len(qs))
        for q, v in zip(qs, vals):
            out[f"p{q}_{name}_s"] = float(v)
    out["percentile_err_s"] = err
    return out


class MetricsAccumulator:
    def __init__(self, interval_s: float = 300.0, telemetry: bool = False):
        self.interval_s = interval_s
        self.responses = []
        self.slas = []
        self.accs = []
        self.waits = []
        self.decisions = []
        self.apps = []
        self.energy_j = 0.0
        self.cost_usd = 0.0
        self.per_worker_tasks = None
        self.intervals = 0
        self.num_containers = 0
        self._telemetry = [] if telemetry else None

    def update(self, stats):
        self.intervals += 1
        self.energy_j += stats.energy_j
        self.cost_usd += stats.cost_usd
        if self.per_worker_tasks is None:
            self.per_worker_tasks = np.zeros_like(stats.per_worker_tasks)
        self.per_worker_tasks += stats.per_worker_tasks
        self.num_containers += int(stats.per_worker_tasks.sum())
        for t in stats.finished:
            self.responses.append(t.response_s)
            self.slas.append(t.sla_s)
            self.accs.append(t.accuracy)
            self.waits.append(t.wait_s)
            self.decisions.append(t.decision)
            self.apps.append(t.app)
        if self._telemetry is not None:
            self._telemetry.append(self._telemetry_row(stats))

    # ---- per-interval telemetry (TELEMETRY_COLS layout) ----
    def _telemetry_row(self, stats):
        fin = stats.finished
        r = np.array([t.response_s for t in fin], np.float64)
        s = np.array([t.sla_s for t in fin], np.float64)
        a = np.array([t.accuracy for t in fin], np.float64)
        w = np.array([t.wait_s for t in fin], np.float64)
        d = np.array([t.decision for t in fin], np.int64)
        util = np.asarray(stats.cpu_util, np.float64)
        return [
            float(len(fin)), float(r.sum()), float((r > s).sum()),
            float(a.sum()),
            float((((r <= s).astype(np.float64) + a) / 2.0).sum()),
            float(w.sum()),
            float((d == 0).sum()), float((d == 1).sum()),
            float((d == 2).sum()),
            0.0,                       # n_dropped: the host never drops
            float(stats.energy_j),
            float(r.min()) if len(fin) else 0.0,
            float(r.max()) if len(fin) else 0.0,
            float(w.min()) if len(fin) else 0.0,
            float(w.max()) if len(fin) else 0.0,
            float(util.mean()), float(util.max()),
            float(stats.num_active + stats.num_waiting),
        ]

    def telemetry_series(self) -> np.ndarray:
        """The accumulated (intervals, len(TELEMETRY_COLS)) series;
        needs ``MetricsAccumulator(telemetry=True)``."""
        if self._telemetry is None:
            raise ValueError("construct MetricsAccumulator(telemetry=True) "
                             "to record per-interval telemetry rows")
        return np.asarray(self._telemetry, np.float64).reshape(
            len(self._telemetry), len(TELEMETRY_COLS))

    def percentiles(self, qs=PERCENTILE_QS) -> dict:
        """EXACT response/wait percentiles over every finished task (the
        host keeps the full sample lists, so no binning error)."""
        out = {}
        for name, vals in (("response", self.responses),
                           ("wait", self.waits)):
            arr = np.percentile(np.asarray(vals, np.float64), qs) \
                if vals else np.zeros(len(qs))
            for q, v in zip(qs, arr):
                out[f"p{q}_{name}_s"] = float(v)
        return out

    # ---- paper metrics ----
    def accuracy(self):                       # eq. 13
        return float(np.mean(self.accs)) if self.accs else 0.0

    def sla_violation_rate(self):             # eq. 14
        if not self.responses:
            return 0.0
        r, s = np.array(self.responses), np.array(self.slas)
        return float(np.mean(r > s))

    def average_reward(self):                  # eq. 15
        if not self.responses:
            return 0.0
        r, s = np.array(self.responses), np.array(self.slas)
        p = np.array(self.accs)
        return float(np.mean(((r <= s).astype(float) + p) / 2.0))

    def avg_response_intervals(self):          # ART in intervals
        return float(np.mean(self.responses) / self.interval_s) if self.responses else 0.0

    def avg_wait_intervals(self):
        return float(np.mean(self.waits) / self.interval_s) if self.waits else 0.0

    def avg_exec_intervals(self):
        if not self.responses:
            return 0.0
        return float((np.mean(self.responses) - np.mean(self.waits)) / self.interval_s)

    def energy_mwhr(self):
        return self.energy_j / 3.6e9           # J -> MW-hr

    def fairness(self):
        """Jain's index over per-worker completed-container counts."""
        x = self.per_worker_tasks
        if x is None or x.sum() == 0:
            return 1.0
        return float(x.sum() ** 2 / (len(x) * np.sum(x ** 2) + 1e-12))

    def cost_per_container(self):
        return self.cost_usd / max(1, self.num_containers)

    def layer_fraction(self):
        d = np.array(self.decisions)
        return float(np.mean(d == 0)) if len(d) else 0.0

    def summary(self):
        return {
            "accuracy": self.accuracy(),
            "sla_violations": self.sla_violation_rate(),
            "reward": self.average_reward(),
            "response_intervals": self.avg_response_intervals(),
            "wait_intervals": self.avg_wait_intervals(),
            "exec_intervals": self.avg_exec_intervals(),
            "energy_mwhr": self.energy_mwhr(),
            "fairness": self.fairness(),
            "cost_per_container": self.cost_per_container(),
            "layer_fraction": self.layer_fraction(),
            "tasks_completed": len(self.responses),
        }
