"""Experiment-level metrics (paper §6.4, eqs. 13–16)."""
from __future__ import annotations

import numpy as np


class MetricsAccumulator:
    def __init__(self, interval_s: float = 300.0):
        self.interval_s = interval_s
        self.responses = []
        self.slas = []
        self.accs = []
        self.waits = []
        self.decisions = []
        self.apps = []
        self.energy_j = 0.0
        self.cost_usd = 0.0
        self.per_worker_tasks = None
        self.intervals = 0
        self.num_containers = 0

    def update(self, stats):
        self.intervals += 1
        self.energy_j += stats.energy_j
        self.cost_usd += stats.cost_usd
        if self.per_worker_tasks is None:
            self.per_worker_tasks = np.zeros_like(stats.per_worker_tasks)
        self.per_worker_tasks += stats.per_worker_tasks
        self.num_containers += int(stats.per_worker_tasks.sum())
        for t in stats.finished:
            self.responses.append(t.response_s)
            self.slas.append(t.sla_s)
            self.accs.append(t.accuracy)
            self.waits.append(t.wait_s)
            self.decisions.append(t.decision)
            self.apps.append(t.app)

    # ---- paper metrics ----
    def accuracy(self):                       # eq. 13
        return float(np.mean(self.accs)) if self.accs else 0.0

    def sla_violation_rate(self):             # eq. 14
        if not self.responses:
            return 0.0
        r, s = np.array(self.responses), np.array(self.slas)
        return float(np.mean(r > s))

    def average_reward(self):                  # eq. 15
        if not self.responses:
            return 0.0
        r, s = np.array(self.responses), np.array(self.slas)
        p = np.array(self.accs)
        return float(np.mean(((r <= s).astype(float) + p) / 2.0))

    def avg_response_intervals(self):          # ART in intervals
        return float(np.mean(self.responses) / self.interval_s) if self.responses else 0.0

    def avg_wait_intervals(self):
        return float(np.mean(self.waits) / self.interval_s) if self.waits else 0.0

    def avg_exec_intervals(self):
        if not self.responses:
            return 0.0
        return float((np.mean(self.responses) - np.mean(self.waits)) / self.interval_s)

    def energy_mwhr(self):
        return self.energy_j / 3.6e9           # J -> MW-hr

    def fairness(self):
        """Jain's index over per-worker completed-container counts."""
        x = self.per_worker_tasks
        if x is None or x.sum() == 0:
            return 1.0
        return float(x.sum() ** 2 / (len(x) * np.sum(x ** 2) + 1e-12))

    def cost_per_container(self):
        return self.cost_usd / max(1, self.num_containers)

    def layer_fraction(self):
        d = np.array(self.decisions)
        return float(np.mean(d == 0)) if len(d) else 0.0

    def summary(self):
        return {
            "accuracy": self.accuracy(),
            "sla_violations": self.sla_violation_rate(),
            "reward": self.average_reward(),
            "response_intervals": self.avg_response_intervals(),
            "wait_intervals": self.avg_wait_intervals(),
            "exec_intervals": self.avg_exec_intervals(),
            "energy_mwhr": self.energy_mwhr(),
            "fairness": self.fairness(),
            "cost_per_container": self.cost_per_container(),
            "layer_fraction": self.layer_fraction(),
            "tasks_completed": len(self.responses),
        }
