"""Discrete-interval mobile-edge simulator (the paper's §6 testbed).

Interval loop (Algorithm 1 environment side):
  1. Poisson arrivals; the policy takes split decisions for new tasks.
  2. The policy produces a placement for all active containers; placements
     are feasibility-repaired against worker RAM; unplaceable tasks wait.
  3. The interval advances in sub-steps: runnable containers share their
     worker's MIPS; layer chains forward activations over the (mobility-
     modulated) network when a stage completes; RAM over-subscription
     triggers swap slowdown.
  4. Leaving tasks yield (response time, accuracy); per-interval AEC/ART,
     energy, cost, fairness are accumulated (eqs. 13–16).

State lives in a structure-of-arrays store (``repro.env.soa.SoAStore``):
tasks are adopted into flat NumPy arrays on first contact and their
``Task``/``Fragment`` objects become thin views, so the object API (tests
and placers mutate ``Fragment.worker``, ``Task.placed`` freely between
intervals) is unchanged while ``advance`` runs as vectorized array
kernels.  ``repro.env.legacy_sim.LegacyEdgeSim`` keeps the original
per-object implementation as the equivalence reference.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.env import soa
from repro.env.cluster import Cluster, make_cluster
from repro.env.mobility import MobilityModel
from repro.env.workload import Task, WorkloadGenerator

NIC_CAP_MB = soa.NIC_CAP_MB  # the paper's 10 MBps NIC ceiling


@dataclasses.dataclass
class IntervalStats:
    t: int
    finished: List[Task]
    energy_j: float
    cost_usd: float
    cpu_util: np.ndarray
    ram_util: np.ndarray
    num_active: int
    num_waiting: int
    per_worker_tasks: np.ndarray


class EdgeSim:
    def __init__(self, cluster: Cluster = None, lam: float = 6.0,
                 seed: int = 0, interval_s: float = 300.0, substeps: int = 30,
                 apps=None, swap_slowdown: float = 0.5):
        self.cluster = cluster or make_cluster()
        self.gen = WorkloadGenerator(lam=lam, seed=seed, apps=apps)
        self.mob = MobilityModel(self.cluster.n, self.cluster.mobile_mask(),
                                 seed=seed + 1)
        self.interval_s = interval_s
        self.substeps = substeps
        self.swap_slowdown = swap_slowdown
        self.t = 0
        self.now = 0.0
        self.active: List[Task] = []
        self.waiting: List[Task] = []
        self.rng = np.random.RandomState(seed + 2)
        self._mips = self.cluster.mips()
        self._ram = self.cluster.ram()
        self._net_bw = self.cluster.net_bw()
        self._lat_mult = np.ones(self.cluster.n)
        self._bw_mult = np.ones(self.cluster.n)
        self._store = soa.SoAStore()
        self._bound_upto = 0   # active-list prefix already adopted

    # ------------------------------------------------------------ state

    def fragment_store(self) -> soa.SoAStore:
        """Adopt any not-yet-bound active tasks and return the SoA store
        (placers use this for vectorized reads).  Tasks enter the active
        list only by appending (``admit`` or direct ``active.append``), so
        only the unscanned suffix needs the adoption check."""
        st = self._store
        if len(self.active) != self._bound_upto:
            pending = False
            for t in self.active[self._bound_upto:]:
                if (t._store is st and t.fragments
                        and t.fragments[0]._store is st):
                    continue
                if not t.fragments:
                    # not realized yet (active.append before realize):
                    # leave unbound and rescan on the next call
                    pending = True
                    continue
                if t._store is st:
                    # re-realized (fragments swapped out): retire old rows
                    st.unbind_task(t)
                st.adopt_task(t)
            if not pending:
                self._bound_upto = len(self.active)
        return st

    def containers(self):
        """All fragments of active tasks, in stable order."""
        out = []
        for task in self.active:
            for f in task.fragments:
                if not f.done:
                    out.append((task, f))
        return out

    def state_features(self):
        """(n_workers, 4): cpu load, ram load, net quality, placed count."""
        return soa.state_features(self.fragment_store(), self._mips,
                                  self._ram, self._lat_mult, self.interval_s)

    # -------------------------------------------------------- placement

    def apply_placement(self, assignment: Dict[int, int]):
        """assignment: fragment key (task_id, idx) -> worker.  Feasibility
        repair: greedy admit in order; RAM-infeasible fragments fall back
        to the least-loaded feasible worker, else the whole task waits.
        (As in the legacy reference, RAM already admitted for a task that
        later fails repair is not rolled back within this pass.)

        Fast path: when every requested placement fits its worker
        outright (the common case — BestFit is RAM-feasibility-aware),
        the sequential repair is provably the identity on the requests
        (each worker's RAM prefix sums are bounded by its final total),
        so the whole pass is applied vectorized.  The per-fragment Python
        loop — the 500-worker hot spot — only runs under RAM pressure,
        and is bit-exact either way."""
        st = self.fragment_store()
        n = self.cluster.n
        F, T = st.n_fragments, st.n_tasks
        if self._bound_upto == len(self.active):
            # every active task is array-bound: try the vectorized path
            req = st.worker[:F].copy()
            task_done = st.task_done[:T]
            if assignment:
                start = st.frag_start[:T]
                count = st.frag_count[:T]
                row_of = {int(tid): ti
                          for ti, tid in enumerate(st.task_id[:T])
                          if not task_done[ti]}
                for (tid, idx), w in assignment.items():
                    ti = row_of.get(tid)
                    if ti is not None and 0 <= idx < count[ti]:
                        req[start[ti] + idx] = w
            live_und = ~st.done[:F]
            valid = req[live_und]
            if valid.size == 0 or ((valid >= 0).all() and (valid < n).all()):
                task_of = st.task_of[:F]
                holds = (~st.chain[:T][task_of]) \
                    | (st.frag_idx[:F] == st.stage[:T][task_of])
                mask = live_und & holds
                demand = np.bincount(req[mask].clip(0),
                                     weights=st.ram_mb[:F][mask],
                                     minlength=n)
                if (demand <= self._ram).all():
                    st.worker[:F] = np.where(st.done[:F], st.worker[:F], req)
                    st.placed[:T] = np.where(task_done, st.placed[:T], True)
                    return
        self._apply_placement_sequential(assignment)

    def _apply_placement_sequential(self, assignment: Dict[int, int]):
        """The reference per-fragment greedy repair (bit-exact vs
        ``LegacyEdgeSim.apply_placement``); used when a request is
        invalid, a task is unbound, or some worker's RAM oversubscribes."""
        st = self.fragment_store()
        n = self.cluster.n
        F, T = st.n_fragments, st.n_tasks
        ram_arr = self._ram
        # hot columns as Python lists: scalar list ops are ~5x faster than
        # NumPy scalar indexing in this sequential repair loop
        worker_l = st.worker[:F].tolist()
        ram_l = st.ram_mb[:F].tolist()
        done_l = st.done[:F].tolist()
        idx_l = st.frag_idx[:F].tolist()
        start_l = st.frag_start[:T].tolist()
        count_l = st.frag_count[:T].tolist()
        chain_l = st.chain[:T].tolist()
        stage_l = st.stage[:T].tolist()
        placed_l = st.placed[:T].tolist()
        ram_cap_l = ram_arr.tolist()
        ram_used = [0.0] * n
        ram_used_np = np.zeros(n)      # mirror for the repair fallbacks
        scratch = np.empty(n)
        get = assignment.get
        for task in self.active:
            if task._store is not st:
                # unrealized (no fragments): trivially placeable, like the
                # legacy loop over an empty fragment list
                task.placed = True
                continue
            ti = task._trow
            row0 = start_l[ti]
            chain = chain_l[ti]
            stg = stage_l[ti]
            tid = task.id
            ok = True
            for k in range(count_l[ti]):
                r = row0 + k
                if done_l[r]:
                    continue
                idx = idx_l[r]
                holds = (not chain) or idx == stg
                w = get((tid, idx), worker_l[r])
                if w < 0 or w >= n:
                    np.divide(ram_used_np, ram_arr, out=scratch)
                    w = int(scratch.argmin())
                if holds and ram_used[w] + ram_l[r] > ram_cap_l[w]:
                    # try least-loaded feasible worker
                    np.subtract(ram_arr, ram_used_np, out=scratch)
                    cand = int(scratch.argmax())
                    if scratch[cand] >= ram_l[r]:
                        w = cand
                    else:
                        ok = False
                        break
                worker_l[r] = w
                if holds:
                    u = ram_used[w] + ram_l[r]
                    ram_used[w] = u
                    ram_used_np[w] = u
            if not ok:
                for k in range(count_l[ti]):
                    worker_l[row0 + k] = -1
            placed_l[ti] = ok
        st.worker[:F] = worker_l
        st.placed[:T] = placed_l

    # --------------------------------------------------------- dynamics
    # (the per-object runnable / holds-RAM predicates live as masks in
    # repro.env.soa — see LegacyEdgeSim for the loop-form spec)

    def advance(self) -> IntervalStats:
        self._lat_mult, self._bw_mult = self.mob.step()
        n = self.cluster.n
        st = self.fragment_store()

        for task in self.waiting:
            task.wait_s += self.interval_s
        for task in self.active:
            # `placed` resolves through the store for adopted tasks
            if not task.placed:
                task.wait_s += self.interval_s

        res = soa.run_interval(st, self._mips, self._ram, self._net_bw,
                               self._bw_mult, self.now, self.interval_s,
                               self.substeps, self.swap_slowdown)
        finished: List[Task] = []
        for ti, fin_now in zip(res.finished_rows, res.finish_now):
            task = st.tasks[ti]
            task.response_s = fin_now - task.arrival_s
            task.accuracy = self.gen.accuracy_of(task)
            finished.append(task)
        self.now = res.now

        # energy, cost
        util = res.busy_time / self.interval_s
        power = self.cluster.power(util)
        energy_j = float(np.sum(power * self.interval_s))
        cost = float(np.sum(self.cluster.cost_hr()) * self.interval_s / 3600.0)

        self.active = [t for t in self.active if not t.done]
        bound = 0
        for t in self.active:
            if t._store is not st:
                break
            bound += 1
        self._bound_upto = bound
        # reclaim retired rows once they dominate the store
        if st.n_tasks > 64 and st.n_tasks - len(self.active) > len(self.active):
            st.compact()
        stats = IntervalStats(self.t, finished, energy_j, cost, util,
                              np.zeros(n), len(self.active),
                              len(self.waiting), res.per_worker_tasks)
        self.t += 1
        return stats

    # ---------------------------------------------------------- arrivals

    def new_interval_tasks(self) -> List[Task]:
        tasks = self.gen.arrivals(self.now) + self.waiting
        self.waiting = []
        return tasks

    def admit(self, tasks: List[Task], decisions):
        """Realize decisions; tasks join the active set (placement next)."""
        for task, d in zip(tasks, decisions):
            if task.decision < 0:
                self.gen.realize(task, int(d))
            self.active.append(task)
