"""Task/workload model — Poisson arrivals of split-able DNN inference jobs.

Applications follow the paper's A = {MNIST, FashionMNIST, CIFAR100} with
AIoTBench-style models (ResNet / MobileNet / Inception families).  Each
task = (batch in [16k, 64k], SLA deadline, app).  A split decision
realizes the task as containers:

  * LAYER (0):      n_frag sequential fragments (precedence chain),
                    intermediate activations forwarded between workers;
  * SEMANTIC (1):   n_branch parallel branches, input broadcast, outputs
                    combined at the broker;
  * COMPRESSED (2): one container with ~55% of the work at an accuracy
                    penalty (the BottleNet++/Gillis arm).

Latency/accuracy envelopes are calibrated against the paper's Fig. 2 and
Table 4 (layer: higher accuracy & response; semantic: lower both).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

LAYER, SEMANTIC, COMPRESSED = 0, 1, 2
APP_NAMES = ["mnist", "fashionmnist", "cifar100"]


@dataclasses.dataclass(frozen=True)
class AppProfile:
    name: str
    minstr_per_sample: float   # mega-instructions per input sample
    feat_kb_per_sample: float  # forwarded activation size (bzip2'd)
    model_mb: tuple            # container image size range (§6.2)
    n_frag: int                # layer-split fragment count
    n_branch: int              # semantic-split branch count
    acc_layer: float
    acc_semantic: float
    base_ram_mb: float         # per-container working set base


APP_PROFILES = [
    AppProfile("mnist",         95.0, 0.40, (8, 14),  4, 2, 0.989, 0.970, 250),
    AppProfile("fashionmnist", 240.0, 1.00, (34, 56), 6, 3, 0.926, 0.886, 420),
    AppProfile("cifar100",     475.0, 2.00, (47, 76), 8, 4, 0.880, 0.815, 600),
]
ACC_COMPRESS_DROP = 0.032     # MC/Gillis compressed-model accuracy penalty
COMPRESS_WORK = 1.00          # BottleNet++ compresses activations, not FLOPs
SEMANTIC_WORK = 0.85           # branches are 1/G-width nets (SplitNet parameter cut)
REF_MIPS = 4019.0             # median worker, for SLA reference times


def _frag_field(name, cast):
    """Property for a Fragment field: plain attribute until the fragment is
    adopted by a structure-of-arrays store, then a view into its row."""
    slot = "_" + name

    def get(self):
        if self._store is None:
            return getattr(self, slot)
        return cast(getattr(self._store, name)[self._row])

    def set_(self, value):
        if self._store is None:
            setattr(self, slot, value)
        else:
            getattr(self._store, name)[self._row] = value

    return property(get, set_)


class Fragment:
    """One container of a realized task.

    Construction-compatible with the former dataclass.  Hot per-substep
    state (``instr_left``, ``worker``, ``done``, ``transfer_left``, …)
    lives in ``repro.env.soa.SoAStore`` arrays once the owning simulator
    adopts the fragment; the attributes here are thin views into that row,
    so object-level reads/writes (tests, placers) stay coherent with the
    vectorized kernels.
    """
    __slots__ = ("task_id", "idx", "_store", "_row", "_instr_left",
                 "_ram_mb", "_out_bytes", "_worker", "_done",
                 "_transfer_left")

    def __init__(self, task_id: int, idx: int, instr_left: float,
                 ram_mb: float, out_bytes: float, worker: int = -1,
                 done: bool = False, transfer_left: float = 0.0):
        self.task_id = task_id
        self.idx = idx
        self._store = None
        self._row = -1
        self._instr_left = instr_left
        self._ram_mb = ram_mb
        self._out_bytes = out_bytes
        self._worker = worker
        self._done = done
        self._transfer_left = transfer_left

    instr_left = _frag_field("instr_left", float)
    ram_mb = _frag_field("ram_mb", float)
    out_bytes = _frag_field("out_bytes", float)
    worker = _frag_field("worker", int)
    done = _frag_field("done", bool)
    transfer_left = _frag_field("transfer_left", float)

    def __repr__(self):
        return (f"Fragment(task_id={self.task_id}, idx={self.idx}, "
                f"instr_left={self.instr_left:.1f}, worker={self.worker}, "
                f"done={self.done})")


def _task_field(name, cast, slot=None):
    slot = slot or "_" + name

    def get(self):
        if self._store is None:
            return getattr(self, slot)
        return cast(getattr(self._store, name)[self._trow])

    def set_(self, value):
        if self._store is None:
            setattr(self, slot, value)
        else:
            getattr(self._store, name)[self._trow] = value

    return property(get, set_)


class Task:
    """A split-able inference job; construction-compatible with the former
    dataclass.  ``chain``/``stage``/``placed``/``done`` become views into
    the owning store once adopted (see ``Fragment``)."""

    def __init__(self, id: int, app: int, batch: int, sla_s: float,
                 arrival_s: float, decision: int = -1, fragments=None,
                 chain: bool = False, stage: int = 0, placed: bool = False,
                 wait_s: float = 0.0, done: bool = False,
                 response_s: float = 0.0, accuracy: float = 0.0):
        self.id = id
        self.app = app
        self.batch = batch
        self.sla_s = sla_s
        self.arrival_s = arrival_s
        self.decision = decision
        self.fragments: List[Fragment] = fragments if fragments is not None \
            else []
        self._store = None
        self._trow = -1
        self._chain = chain
        self._stage = stage            # active fragment in a layer chain
        self._placed = placed
        self._done = done
        self.wait_s = wait_s
        self.response_s = response_s
        self.accuracy = accuracy

    chain = _task_field("chain", bool)
    stage = _task_field("stage", int)
    placed = _task_field("placed", bool)
    done = _task_field("task_done", bool, slot="_done")

    def __repr__(self):
        return (f"Task(id={self.id}, app={self.app}, decision="
                f"{self.decision}, stage={self.stage}, done={self.done})")


def layer_ref_response_s(app: int) -> float:
    """Unloaded single-worker reference execution time of a layer chain
    (used for SLA sampling and as the MAB's ground-truth-ish scale)."""
    p = APP_PROFILES[app]
    batch = 40000
    return p.minstr_per_sample * batch / REF_MIPS


class WorkloadGenerator:
    def __init__(self, lam: float = 6.0, seed: int = 0, apps=None,
                 tight_frac: float = 0.55, tight=(0.35, 1.15),
                 loose=(2.2, 3.5)):
        """SLA deadlines follow the Gillis-style bimodal mix the paper
        uses: a latency-critical class (deadline below the typical
        contended layer-split response, ~3.3x the unloaded reference) and
        a loose class above it — in units of the app's unloaded reference
        execution time, batch-scaled."""
        self.lam = lam
        self.rng = np.random.RandomState(seed)
        self.apps = apps if apps is not None else [0, 1, 2]
        self.tight_frac = tight_frac
        self.tight, self.loose = tight, loose
        self._next_id = 0

    def arrivals(self, now_s: float) -> List[Task]:
        n = self.rng.poisson(self.lam)
        tasks = []
        for _ in range(n):
            app = int(self.rng.choice(self.apps))
            batch = int(self.rng.randint(16000, 64001))
            ref = layer_ref_response_s(app) * batch / 40000.0
            band = self.tight if self.rng.rand() < self.tight_frac \
                else self.loose
            sla = ref * self.rng.uniform(*band)
            tasks.append(Task(id=self._next_id, app=app, batch=batch,
                              sla_s=sla, arrival_s=now_s))
            self._next_id += 1
        return tasks

    def realize(self, task: Task, decision: int,
                img_mb: float = None) -> Task:
        """Materialize the container workflow for a split decision.

        ``img_mb`` overrides the container-image-size draw — the dual
        trace compiler (``repro.env.jaxsim.arrays.compile_trace_dual``)
        draws it once per task and realizes *both* split variants from the
        same image, keeping its RNG stream position identical to the
        single-variant compile."""
        p = APP_PROFILES[task.app]
        total_mi = p.minstr_per_sample * task.batch
        feat_bytes = p.feat_kb_per_sample * 1024.0 * task.batch
        if img_mb is None:
            img_mb = self.rng.uniform(*p.model_mb)
        ram_batch = p.base_ram_mb * task.batch / 40000.0
        task.decision = decision
        task.fragments = []
        if decision == LAYER:
            task.chain = True
            per = total_mi / p.n_frag
            for i in range(p.n_frag):
                out = feat_bytes if i < p.n_frag - 1 else feat_bytes * 0.05
                task.fragments.append(Fragment(
                    task.id, i, per, img_mb / p.n_frag + ram_batch / 2.0, out))
        elif decision == SEMANTIC:
            task.chain = False
            per = total_mi * SEMANTIC_WORK / p.n_branch
            for i in range(p.n_branch):
                task.fragments.append(Fragment(
                    task.id, i, per,
                    img_mb / p.n_branch + ram_batch / 2.5,
                    feat_bytes * 0.02))
        else:  # COMPRESSED
            task.chain = False
            # monolithic container: whole (compressed) model + full batch in
            # one RAM footprint — the memory bottleneck the paper targets
            task.fragments.append(Fragment(
                task.id, 0, total_mi * COMPRESS_WORK,
                img_mb * 0.5 + ram_batch * 3.0, feat_bytes * 0.02))
        return task

    def accuracy_of(self, task: Task) -> float:
        return accuracy_from_noise(task.app, task.decision,
                                   self.rng.normal(0, 0.003))


def accuracy_from_noise(app: int, decision: int, noise: float) -> float:
    """Accuracy of a (app, split decision) pair given a pre-drawn noise
    sample — lets the dual trace compiler evaluate both split variants of
    one task from a single draw (the variant only shifts the base)."""
    p = APP_PROFILES[app]
    base = {LAYER: p.acc_layer, SEMANTIC: p.acc_semantic,
            COMPRESSED: p.acc_layer - ACC_COMPRESS_DROP}[decision]
    return float(np.clip(base + noise, 0, 1))
