"""Streaming serve driver: continuous arrivals through chunked,
carry-re-entrant interval programs.

Everything else in ``repro.env.jaxsim`` runs fixed-Γ episodes compiled
up front; this module is the always-on serving mode the paper's setting
implies — tasks arrive continuously and the policy engine must keep
deciding and placing under a live deadline stream:

  * a host **feeder** (``StreamFeeder``) generates Poisson arrivals
    incrementally — the same ``WorkloadGenerator``/``MobilityModel``
    choreography as ``arrays.compile_trace(_dual)``, but stateful, so
    the sim clock, mobility walk and task ids continue forever — and
    emits fixed-shape *chunk tapes* of ``chunk_intervals`` intervals;
  * the **ring buffer** is the fixed-capacity slot store itself
    (``kernels.init_state``): ``max_active`` device-resident task slots
    that arrivals scatter into and finished tasks vacate.  Admission is
    counted-not-silent twice over: arrivals beyond the tape's
    ``max_arrivals`` rows are dropped host-side and counted
    (``feeder_overflow``), arrivals beyond free slot capacity are
    dropped in-kernel and counted (``state["dropped"]``);
  * the jitted chunk program (``driver._stream_program``) takes the
    carry ``(state, acc, engine_state)`` as an argument and returns it,
    so consecutive chunks continue ONE endless episode.  The chunk
    length is the only new static — one compile per chunk shape — and
    the carry is **donated** chunk-to-chunk wherever the backend
    supports it, so a 16k-interval soak never holds two copies of the
    slot arrays.  The carry never round-trips to host mid-stream
    (``StreamRunner`` asserts the donated previous carry actually died);
  * ``serve`` overlaps the two: a feeder thread fills chunk N+1's
    arrival tape into a small queue while the device executes chunk N
    (double buffering — jitted executions release the GIL), with ledger
    spans for both sides so the overlap is visible in the run ledger;
  * rolling metrics (``RollingMetrics``) replace end-of-episode
    summaries: QPS, p50/p99 response, deadline-violation rate and ring
    occupancy over a sliding window of the per-interval telemetry rows
    the chunk program always records (``metrics.TELEMETRY_COLS`` + the
    engine's learning-signal columns).

``replay_stream`` drives the same machinery over a frozen compiled
trace (``arrays.chunk_tapes``); because engine hooks see the absolute
interval index (``driver._ShiftedLeaf``), the chunked replay equals the
one-shot ``run_trace_engine`` episode to float tolerance — the parity
contract ``tests/test_stream.py`` pins at rtol=1e-4.
"""
from __future__ import annotations

import queue
import threading
from collections import deque
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.env.cluster import Cluster, make_cluster
from repro.env.jaxsim import driver, engines, kernels
from repro.env.jaxsim.arrays import (ClusterArrays, chunk_tapes,
                                     default_capacity)
from repro.env.metrics import TELEMETRY_COLS, series_percentiles
from repro.env.mobility import MobilityModel
from repro.env.workload import (APP_PROFILES, WorkloadGenerator,
                                accuracy_from_noise)
from repro.obs import get_ledger


def _default_max_arrivals(lam: float) -> int:
    """Arrival-row capacity of one tape interval: the Poisson mean plus
    an 8-sigma margin, so overflow is astronomically rare at steady
    state yet still *counted* when a burst exceeds it."""
    return int(np.ceil(lam + 8.0 * np.sqrt(max(lam, 1.0)) + 4.0))


def _max_frags(apps) -> int:
    """Fragment-column capacity covering any split decision of the
    selected apps (layer chains and semantic branches both)."""
    return max(max(APP_PROFILES[a].n_frag, APP_PROFILES[a].n_branch, 1)
               for a in apps)


class StreamFeeder:
    """Incremental host-side tape compiler for the serving loop.

    Carries the ``WorkloadGenerator``, ``MobilityModel`` and sim clock
    across calls, so consecutive ``next_chunk`` tapes continue one
    endless workload — the streaming analogue of
    ``arrays.compile_trace`` (pass ``decider``) or ``compile_trace_dual``
    (pass ``variants``), with identical per-task RNG choreography.

    Shapes are fixed for the stream's lifetime (``max_arrivals`` rows
    per interval, ``max_frags`` fragment columns), so every chunk shares
    one compiled executable.  Arrivals beyond ``max_arrivals`` in a
    burst interval are dropped host-side and counted in ``overflow`` —
    never silently truncated; the running totals satisfy
    ``offered == fed + overflow``.
    """

    def __init__(self, lam: float = 6.0, seed: int = 0,
                 interval_s: float = 300.0, substeps: int = 30,
                 cluster: Optional[Cluster] = None, apps=None,
                 max_arrivals: Optional[int] = None,
                 decider=None, variants=None):
        if (decider is None) == (variants is None):
            raise ValueError("pass exactly one of decider= (static "
                             "single-variant tapes) or variants= (dual "
                             "tapes for in-kernel deciders)")
        self.lam = lam
        self.seed = seed
        self.interval_s = interval_s
        self.substeps = substeps
        self.cluster = cluster or make_cluster()
        self.apps = list(apps) if apps is not None else [0, 1, 2]
        self.decider = decider
        self.variants = tuple(variants) if variants is not None else None
        self.max_arrivals = max_arrivals if max_arrivals is not None \
            else _default_max_arrivals(lam)
        self.max_frags = _max_frags(self.apps)
        self.gen = WorkloadGenerator(lam=lam, seed=seed, apps=self.apps)
        self.mob = MobilityModel(self.cluster.n,
                                 self.cluster.mobile_mask(), seed=seed + 1)
        self.now = 0.0
        self.n_intervals = 0
        # counted-not-silent admission ledger (host half)
        self.offered = 0       # tasks the Poisson process generated
        self.fed = 0           # tasks written into tapes
        self.overflow = 0      # tasks dropped for exceeding max_arrivals
        # the placer sees the PREVIOUS interval's mobility latency draw
        # (compile_trace_dual's lat_prev row-0-ones convention, continued
        # across chunks)
        self._lat_prev = np.ones(self.cluster.n, np.float64)

    # ------------------------------------------------------------ tapes

    def _arrivals(self):
        """One interval's admitted tasks, with overflow counted."""
        tasks = self.gen.arrivals(self.now)
        self.offered += len(tasks)
        if len(tasks) > self.max_arrivals:
            self.overflow += len(tasks) - self.max_arrivals
            tasks = tasks[:self.max_arrivals]
        self.fed += len(tasks)
        return tasks

    def next_chunk(self, n_intervals: int) -> dict:
        """Generate the next ``n_intervals`` intervals as a chunk tape
        (the ``kernel_dict`` layout of ``TraceArrays`` /
        ``DualTraceArrays``, chunk-local T axis)."""
        T, A, F = n_intervals, self.max_arrivals, self.max_frags
        dt = self.interval_s / self.substeps
        if self.variants is None:
            tape = self._next_chunk_static(T, A, F, dt)
        else:
            tape = self._next_chunk_dual(T, A, F, dt)
        self.n_intervals += T
        return tape

    def _next_chunk_static(self, T, A, F, dt):
        tape = {
            "bw_mult": np.ones((T, self.cluster.n), np.float64),
            "valid": np.zeros((T, A), bool),
            "sla": np.zeros((T, A), np.float64),
            "arrival_s": np.zeros((T, A), np.float64),
            "app": np.zeros((T, A), np.int32),
            "batch": np.zeros((T, A), np.int64),
            "acc": np.zeros((T, A), np.float64),
            "decision": np.full((T, A), -1, np.int32),
            "chain": np.zeros((T, A), bool),
            "nfrag": np.zeros((T, A), np.int32),
            "instr": np.zeros((T, A, F), np.float64),
            "ram": np.zeros((T, A, F), np.float64),
            "out_bytes": np.zeros((T, A, F), np.float64),
        }
        for t in range(T):
            tasks = self._arrivals()
            decisions = self.decider.decide(tasks)
            for a, (task, d) in enumerate(zip(tasks, decisions)):
                self.gen.realize(task, int(d))
                acc = self.gen.accuracy_of(task)
                tape["valid"][t, a] = True
                tape["sla"][t, a] = task.sla_s
                tape["arrival_s"][t, a] = task.arrival_s
                tape["app"][t, a] = task.app
                tape["batch"][t, a] = task.batch
                tape["acc"][t, a] = acc
                tape["decision"][t, a] = task.decision
                tape["chain"][t, a] = task.chain
                tape["nfrag"][t, a] = len(task.fragments)
                for i, f in enumerate(task.fragments):
                    tape["instr"][t, a, i] = f.instr_left
                    tape["ram"][t, a, i] = f.ram_mb
                    tape["out_bytes"][t, a, i] = f.out_bytes
            _, bw = self.mob.step()
            tape["bw_mult"][t] = bw
            for _ in range(self.substeps):
                self.now += dt
        return tape

    def _next_chunk_dual(self, T, A, F, dt):
        n = self.cluster.n
        tape = {
            "bw_mult": np.ones((T, n), np.float64),
            "lat_prev": np.ones((T, n), np.float64),
            "valid": np.zeros((T, A), bool),
            "sla": np.zeros((T, A), np.float64),
            "arrival_s": np.zeros((T, A), np.float64),
            "app": np.zeros((T, A), np.int32),
            "batch": np.zeros((T, A), np.int64),
            "vacc": np.zeros((T, A, 2), np.float64),
            "vchain": np.zeros((T, A, 2), bool),
            "vnfrag": np.zeros((T, A, 2), np.int32),
            "vinstr": np.zeros((T, A, 2, F), np.float64),
            "vram": np.zeros((T, A, 2, F), np.float64),
            "vout": np.zeros((T, A, 2, F), np.float64),
        }
        for t in range(T):
            tasks = self._arrivals()
            for a, task in enumerate(tasks):
                img_mb = self.gen.rng.uniform(
                    *APP_PROFILES[task.app].model_mb)
                tape["valid"][t, a] = True
                tape["sla"][t, a] = task.sla_s
                tape["arrival_s"][t, a] = task.arrival_s
                tape["app"][t, a] = task.app
                tape["batch"][t, a] = task.batch
                for v, d in enumerate(self.variants):
                    self.gen.realize(task, d, img_mb=img_mb)
                    tape["vchain"][t, a, v] = task.chain
                    tape["vnfrag"][t, a, v] = len(task.fragments)
                    for i, f in enumerate(task.fragments):
                        tape["vinstr"][t, a, v, i] = f.instr_left
                        tape["vram"][t, a, v, i] = f.ram_mb
                        tape["vout"][t, a, v, i] = f.out_bytes
                noise = self.gen.rng.normal(0, 0.003)
                for v, d in enumerate(self.variants):
                    tape["vacc"][t, a, v] = accuracy_from_noise(
                        task.app, d, noise)
            tape["lat_prev"][t] = self._lat_prev
            lat, bw = self.mob.step()
            tape["bw_mult"][t] = bw
            self._lat_prev = lat
            for _ in range(self.substeps):
                self.now += dt
        return tape


class StreamRunner:
    """Chunked executor of the carry-re-entrant interval program.

    Holds the device-resident carry ``(slot state, accumulators,
    engine_state)`` between ``run_chunk`` calls; each call advances the
    stream by one chunk tape and returns that chunk's per-interval
    telemetry rows (the only per-chunk device→host transfer).  The carry
    itself NEVER round-trips mid-stream: it stays a committed jax.Array
    pytree, and with backend donation support the previous chunk's
    buffers are reused in place — ``run_chunk`` asserts the donated
    carry actually died, which doubles as the no-copy proof."""

    def __init__(self, engine, es0, *, interval_s: float, substeps: int,
                 max_active: int, cluster: Optional[Cluster] = None,
                 swap_slowdown: float = 0.5,
                 substep_impl: Optional[str] = None):
        self.engine = engine
        self.cluster = cluster or make_cluster()
        self.cl = ClusterArrays.from_cluster(self.cluster)
        self.interval_s = float(interval_s)
        self.substeps = int(substeps)
        self.K = int(max_active)
        self.swap_slowdown = swap_slowdown
        self.impl = driver._resolve_substep_impl(substep_impl)
        self.tcols = tuple(TELEMETRY_COLS) + tuple(engine.telemetry_cols())
        self.t0 = 0
        self.n_chunks = 0
        self.donated = driver._donation_ok()
        self._es0 = es0
        self.carry = None          # built on the first chunk (needs F)
        with enable_x64():
            self._cld = {k: jnp.asarray(v)
                         for k, v in self.cl.as_dict().items()}

    def _ensure_carry(self, F: int):
        if self.carry is not None:
            return
        with enable_x64():
            state = kernels.init_state(self.K, F, self.cl.n)
            acc = driver._init_acc(self.cl.n)
            es = jax.tree_util.tree_map(jnp.asarray, self._es0)
        self.carry = (state, acc, es)

    def run_chunk(self, tape: dict) -> np.ndarray:
        """Advance the stream by one chunk tape; returns the chunk's
        ``(T, C)`` float64 telemetry series as NumPy."""
        with enable_x64():
            leaves = {k: jnp.asarray(v) for k, v in tape.items()}
            frag = leaves["vinstr"] if "vinstr" in leaves \
                else leaves["instr"]
            self._ensure_carry(int(frag.shape[-1]))
            key = driver._static_key(self.engine, leaves, self.K,
                                     self.cl.n, self.substeps,
                                     self.interval_s, self.swap_slowdown,
                                     self.impl, "stream")
            runner = driver._get_stream_runner(key)
            prev = self.carry
            carry, series = runner(leaves, self._cld, prev,
                                   jnp.asarray(self.t0, jnp.int64))
        leaf = jax.tree_util.tree_leaves(carry)[0]
        assert isinstance(leaf, jax.Array), \
            "streaming carry left the device"
        if self.donated:
            jax.block_until_ready(leaf)
            prev_leaf = jax.tree_util.tree_leaves(prev)[0]
            # the donated input dying in place is the proof that the
            # chunk-to-chunk carry is updated without a second copy of
            # the slot arrays (and never round-trips through the host)
            assert prev_leaf.is_deleted(), \
                "streaming carry was copied instead of donated"
        self.carry = carry
        self.t0 += int(tape["valid"].shape[0])
        self.n_chunks += 1
        return np.asarray(series)

    # --------------------------------------------------------- summary

    def raw_outputs(self) -> dict:
        """Pull the final accumulators to host (the stream's ONLY carry
        round-trip — call it once, after the last chunk)."""
        state, acc, es = self.carry
        out = {"metrics": acc["metrics"], "energy": acc["energy"],
               "pwt": acc["pwt"], "dropped": state["dropped"],
               "live": jnp.sum(state["alive"])}
        out.update(self.engine.outputs(es))
        return jax.tree_util.tree_map(np.asarray, out)

    def summary(self, n_intervals: Optional[int] = None) -> dict:
        """Assemble the §6.4 summary over everything streamed so far."""
        out = self.raw_outputs()
        s = driver._summarize(out, self.interval_s,
                              n_intervals or self.t0,
                              float(self.cl.cost_hr.sum()))
        return self.engine.summarize(out, s)


class RollingMetrics:
    """Sliding-window serving metrics over interval-telemetry rows:
    QPS (completions per sim-second), binned p50/p95/p99 response and
    wait percentiles (``metrics.series_percentiles`` with its
    ``percentile_err_s`` bound), deadline-violation rate and mean ring
    occupancy — all over the trailing ``window_intervals`` intervals."""

    def __init__(self, cols, window_intervals: int, interval_s: float):
        self.cols = list(cols)
        self.interval_s = float(interval_s)
        self.window = deque(maxlen=int(window_intervals))
        self._i = {c: i for i, c in enumerate(self.cols)}

    def update(self, series) -> None:
        for row in np.asarray(series, np.float64):
            self.window.append(row)

    def snapshot(self) -> dict:
        if not self.window:
            return {"window_intervals": 0, "qps": 0.0,
                    "violation_rate": 0.0, "occupancy_mean": 0.0}
        w = np.stack(self.window)
        n_fin = float(w[:, self._i["n_fin"]].sum())
        snap = {
            "window_intervals": len(self.window),
            "qps": n_fin / (len(self.window) * self.interval_s),
            "violation_rate":
                float(w[:, self._i["n_viol"]].sum()) / max(n_fin, 1.0),
            "occupancy_mean": float(w[:, self._i["n_active"]].mean()),
            "dropped": float(w[:, self._i["n_dropped"]].sum()),
        }
        snap.update(series_percentiles(w, self.cols))
        return snap


def replay_stream(engine, trace, es0, *, chunk_intervals: int,
                  cluster: Optional[Cluster] = None,
                  max_active: Optional[int] = None,
                  swap_slowdown: float = 0.5,
                  substep_impl: Optional[str] = None,
                  collect_series: bool = False) -> dict:
    """Chunked streaming replay of a frozen compiled trace.

    Splits ``trace`` into ``chunk_intervals``-sized tapes and threads
    the carry through consecutive chunk calls; the resulting summary
    equals the one-shot ``driver.run_trace_engine`` episode within the
    standard rtol=1e-4 summary-metric contract (the per-interval math is
    identical — only the fori_loop boundaries move).  With
    ``collect_series`` the summary also carries the concatenated
    telemetry series + percentile estimates, mirroring
    ``telemetry="interval"`` episodes."""
    cluster = cluster or make_cluster()
    K = max_active or default_capacity([trace])
    r = StreamRunner(engine, es0, interval_s=trace.interval_s,
                     substeps=trace.substeps, max_active=K,
                     cluster=cluster, swap_slowdown=swap_slowdown,
                     substep_impl=substep_impl)
    led = get_ledger()
    chunks = []
    for t0, tape in chunk_tapes(trace, chunk_intervals):
        with led.span("stream_chunk", engine=engine.name, idx=r.n_chunks,
                      t0=t0, n_intervals=int(tape["valid"].shape[0])):
            chunks.append(r.run_chunk(tape))
    s = r.summary(trace.n_intervals)
    if collect_series:
        series = np.concatenate(chunks, axis=0)
        s.update(series_percentiles(series, r.tcols))
        s["telemetry"] = {"cols": list(r.tcols), "series": series}
    return s


def serve(engine, es0, feeder: StreamFeeder, *, chunk_intervals: int = 64,
          max_active: int = 512, target_tasks: int = 10_000,
          window_intervals: int = 256, prefetch: int = 2,
          swap_slowdown: float = 0.5,
          substep_impl: Optional[str] = None, on_chunk=None) -> dict:
    """The always-on serving loop: stream Poisson arrivals through the
    chunked interval program until the feeder has offered at least
    ``target_tasks`` tasks, overlapping host tape generation with device
    compute.

    A daemon feeder thread fills a ``prefetch``-deep queue with chunk
    tapes (``prefetch=2`` is classic double buffering: chunk N+1's tape
    is generated while chunk N executes — jitted executions release the
    GIL); the main thread drains it through a ``StreamRunner`` whose
    carry is donated chunk to chunk.  ``on_chunk(i, runner, rolling)``
    fires after every chunk (progress printing, RSS sampling).

    Returns the serving report: admission ledger (``offered == fed +
    feeder_overflow``, ``admitted == fed - dropped``, ``admitted ==
    finished + live``), ring-occupancy stats (first-half vs second-half
    means — the flat-memory soak criterion), the rolling-window
    snapshot, and the cumulative §6.4 summary."""
    runner = StreamRunner(engine, es0, interval_s=feeder.interval_s,
                          substeps=feeder.substeps, max_active=max_active,
                          cluster=feeder.cluster,
                          swap_slowdown=swap_slowdown,
                          substep_impl=substep_impl)
    rolling = RollingMetrics(runner.tcols, window_intervals,
                             feeder.interval_s)
    led = get_ledger()
    parent = led.current_span()
    q: "queue.Queue" = queue.Queue(maxsize=max(1, int(prefetch)))
    stop = threading.Event()
    feed_err = []

    def _feed():
        try:
            while not stop.is_set() and feeder.offered < target_tasks:
                t0 = feeder.n_intervals
                with led.span("feed", parent=parent, t0=t0,
                              n_intervals=chunk_intervals):
                    tape = feeder.next_chunk(chunk_intervals)
                q.put(tape)
        except BaseException as e:  # surfaced to the caller below
            feed_err.append(e)
        finally:
            q.put(None)

    occupancy = []
    i_active = runner.tcols.index("n_active")
    with led.span("serve", engine=engine.name, capacity=max_active,
                  chunk_intervals=chunk_intervals,
                  target_tasks=target_tasks):
        th = threading.Thread(target=_feed, name="stream-feeder",
                              daemon=True)
        th.start()
        try:
            while True:
                tape = q.get()
                if tape is None:
                    break
                with led.span("stream_chunk", engine=engine.name,
                              idx=runner.n_chunks, t0=runner.t0,
                              n_intervals=int(tape["valid"].shape[0]),
                              n_tasks=int(tape["valid"].sum())):
                    series = runner.run_chunk(tape)
                rolling.update(series)
                occupancy.append(series[:, i_active])
                if on_chunk is not None:
                    on_chunk(runner.n_chunks, runner, rolling)
        finally:
            stop.set()
            th.join()
    if feed_err:
        raise feed_err[0]
    summary = runner.summary()
    out = runner.raw_outputs()
    occ = np.concatenate(occupancy) if occupancy else np.zeros(1)
    h = len(occ) // 2
    dropped = int(out["dropped"])
    return {
        "engine": engine.name,
        "chunk_intervals": chunk_intervals,
        "window_intervals": window_intervals,
        "capacity": max_active,
        "n_chunks": runner.n_chunks,
        "n_intervals": runner.t0,
        "offered": feeder.offered,
        "fed": feeder.fed,
        "feeder_overflow": feeder.overflow,
        "dropped": dropped,
        "admitted": feeder.fed - dropped,
        "finished": int(summary["tasks_completed"]),
        "live": int(out["live"]),
        "max_occupancy": float(occ.max()),
        "occupancy_mean_first_half": float(occ[:h].mean()) if h else 0.0,
        "occupancy_mean_second_half": float(occ[h:].mean()),
        "rolling": rolling.snapshot(),
        "summary": summary,
    }


def make_stream_policy(policy: str, *, cluster: Optional[Cluster] = None,
                       seed: int = 0, mab_state=None, daso_theta=None,
                       daso_cfg=None, gillis_state=None, num_apps: int = 3):
    """Resolve a policy name into ``(engine, es0, feeder_kwargs)`` for
    the serving loop — the streaming analogue of the
    ``run_*_arrays*`` wrapper layer.

    Static BestFit policies (``policies.STATIC_POLICIES``) get a host
    decider feeder; the learned policies get dual-variant feeders with
    their engine state: ``"mab"``/``"splitplace"`` continue a pretrained
    ``mab_state`` (fresh ``mab.init_state`` when None — cold-start
    serving), ``"splitplace"``/``"mab+gobi"`` add the frozen DASO
    surrogate, ``"gillis"`` carries its Q-table/ε."""
    cluster = cluster or make_cluster()
    from repro.env.jaxsim import policies as pol
    if policy in pol.STATIC_POLICIES:
        dec = pol.make_static_decider(policy, mab_state=mab_state)
        return engines.StaticEngine(), (), {"decider": dec}
    if policy in ("mab", "splitplace", "mab+gobi"):
        if mab_state is None:
            from repro.core import mab
            mab_state = mab.init_state(num_apps)
        cfg = daso_cfg
        if policy == "mab+gobi" and cfg is not None:
            cfg = cfg._replace(decision_aware=False)
        if policy == "mab":
            cfg = None
        theta = driver._check_learned_args(cfg, daso_theta, cluster.n)
        engine = engines.MABDeployEngine(mab_hp=tuple(driver.MAB_HP),
                                         daso_cfg=cfg)
        return engine, driver._deploy_es(mab_state, theta), \
            {"variants": engines.MAB_VARIANTS}
    if policy == "gillis":
        engine = engines.GillisEngine(gillis_hp=tuple(driver.GILLIS_HP))
        es0 = driver._gillis_es(gillis_state,
                                driver.trace_train_key(seed), num_apps,
                                driver.GILLIS_HP[0])
        return engine, es0, {"variants": engines.GILLIS_VARIANTS}
    raise ValueError(f"unknown streaming policy {policy!r} (want one of "
                     f"{pol.STATIC_POLICIES + ('mab', 'splitplace', 'mab+gobi', 'gillis')})")
