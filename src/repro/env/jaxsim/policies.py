"""Array-backend policy surface: static deciders + the in-kernel BestFit.

The jitted backend realizes fragments at trace-compile time, so its
deciders must be *static*: a pure function of the task (and optionally a
frozen learned state), with no interval-feedback loop.  Covered here:

  * fixed LAYER / SEMANTIC / COMPRESSED (the paper's L+*, S+*, MC arms);
  * ``roundrobin`` — the i % 3 mixed-decision trace the throughput and
    equivalence suites use;
  * ``threshold``  — deadline-vs-reference heuristic (layer when the SLA
    clears 1.6× the unloaded layer-chain reference, else semantic —
    the Gillis-style context split without the Q-loop);
  * ``mab-static`` — UCB deployment decisions (eq. 9) from a *frozen*
    pretrained ``MABState``; the ε-greedy training loop stays on the
    host backend.

Placement for the static deciders is the vectorized BestFit kernel
(``kernels.place``); the learned policies below run their full loop —
including ``mode="train"`` ε-greedy exploration and DASO finetuning —
inside the kernel.  Every decider here also satisfies the host
``Decider`` protocol (``decide``/``feedback``), so the same object can
drive ``run_trace`` on the SoA backend for apples-to-apples
benchmarking.
"""
from __future__ import annotations

from typing import List

from repro.env.workload import (COMPRESSED, LAYER, SEMANTIC,
                                layer_ref_response_s)

#: policy names the jitted backend accepts (all BestFit-placed)
STATIC_POLICIES = ("mc", "bestfit-layer", "bestfit-semantic", "bestfit-rr",
                   "bestfit-threshold", "bestfit-mab")

#: policies whose learning loop runs *inside* the jitted kernel, each an
#: engine instance over the unified interval program (see
#: ``repro.env.jaxsim.engines``).  The MAB family ("mab", "splitplace",
#: "mab+gobi") carries ``MABState`` through the carry (online decisions
#: + Algorithm-1 feedback): "splitplace" adds the array-form DASO
#: placer, "mab+gobi" the decision-blind GOBI ablation of the same
#: surrogate machinery, "mab" places with plain BestFit.  Each supports
#: two modes — ``"deploy"`` (UCB decisions, frozen pretrained
#: surrogate) and ``"train"`` (ε-greedy decisions + in-kernel DASO
#: finetuning through a carried replay window).  "gillis" carries the
#: baseline's contextual Q-table/ε instead (its ε-greedy Q-loop is
#: inherently online; ``mode`` is ignored).  All consume dual-variant
#: traces (``arrays.compile_trace_dual``) since the split decision is no
#: longer known at trace-compile time — Gillis traces realize
#: (LAYER, COMPRESSED) rather than (LAYER, SEMANTIC).
LEARNED_POLICIES = ("mab", "splitplace", "mab+gobi", "gillis")

#: the subset that consumes a pretrained ``MABState``
MAB_LEARNED_POLICIES = ("mab", "splitplace", "mab+gobi")

#: the subset that consumes the pretrained DASO surrogate (theta + cfg);
#: "mab+gobi" reuses the same theta with the decision one-hot slice of
#: the surrogate input zeroed (``daso_cfg.decision_aware=False``)
DASO_LEARNED_POLICIES = ("splitplace", "mab+gobi")


class StaticFixedDecider:
    def __init__(self, decision: int, name: str):
        self.decision = decision
        self.name = name

    def decide(self, tasks) -> List[int]:
        return [self.decision] * len(tasks)

    def feedback(self, finished):
        pass


class RoundRobinDecider:
    """i % 3 over each interval's arrivals (the sim_throughput trace)."""
    name = "bestfit-rr"

    def decide(self, tasks) -> List[int]:
        return [i % 3 for i in range(len(tasks))]

    def feedback(self, finished):
        pass


class ThresholdDecider:
    """LAYER when the deadline clears ``margin``× the unloaded layer-split
    reference time (batch-scaled), else SEMANTIC."""
    name = "bestfit-threshold"

    def __init__(self, margin: float = 1.6):
        self.margin = margin

    def decide(self, tasks) -> List[int]:
        out = []
        for t in tasks:
            ref = layer_ref_response_s(t.app) * t.batch / 40000.0
            out.append(LAYER if t.sla_s >= self.margin * ref else SEMANTIC)
        return out

    def feedback(self, finished):
        pass


class StaticMABDecider:
    """Frozen-state UCB decisions (deploy-mode MAB without the feedback
    loop — the state never changes, so decisions are trace-compilable)."""
    name = "bestfit-mab"

    def __init__(self, state, ucb_c: float = 0.5):
        if state is None:
            raise ValueError("bestfit-mab needs a pretrained mab_state")
        from repro.core import mab as mab_mod
        self._mab = mab_mod
        self.state = state
        self.ucb_c = ucb_c

    def decide(self, tasks) -> List[int]:
        import jax.numpy as jnp
        out = []
        for t in tasks:
            sla = jnp.float32(t.sla_s * 40000.0 / max(t.batch, 1))
            d, _ = self._mab.decide_ucb(self.state, sla, t.app, self.ucb_c)
            out.append(int(d))
        return out

    def feedback(self, finished):
        pass


def make_static_decider(policy: str, mab_state=None,
                        seed: int = 0):
    """Resolve a jitted-backend policy name to its compile-time decider."""
    del seed  # static deciders are deterministic
    table = {
        "mc": lambda: StaticFixedDecider(COMPRESSED, "mc"),
        "bestfit-layer": lambda: StaticFixedDecider(LAYER, "bestfit-layer"),
        "bestfit-semantic": lambda: StaticFixedDecider(SEMANTIC,
                                                       "bestfit-semantic"),
        "bestfit-rr": RoundRobinDecider,
        "bestfit-threshold": ThresholdDecider,
        "bestfit-mab": lambda: StaticMABDecider(mab_state),
    }
    if policy not in table:
        raise ValueError(
            f"policy {policy!r} is not static (jit backend supports "
            f"{STATIC_POLICIES}; learning deciders/placers need "
            f"backend='soa')")
    return table[policy]()


def host_policy(policy: str, mab_state=None, seed: int = 0):
    """The same (static decider, BestFit) pair as a host ``Policy`` object
    for the SoA interval loop — used by benchmarks to compare backends on
    identical policy behaviour."""
    from repro.core.splitplace import BestFitPlacer, Policy
    return Policy(policy, make_static_decider(policy, mab_state, seed),
                  BestFitPlacer())
