"""Host-side trace compilation into fixed-capacity padded arrays.

The jitted simulator (``repro.env.jaxsim.kernels`` / ``driver``) cannot
draw Poisson arrivals or realize fragments inside ``lax.fori_loop`` —
the workload generator is NumPy ``RandomState`` driven and allocates
per-task objects.  Instead the *trace* (arrivals, realized fragments,
mobility multipliers, pre-sampled accuracies) is compiled host-side into
dense padded arrays once, and the accelerator kernel only runs the
physics + placement over them.  Compilation is O(tasks) and trivially
cheap next to the interval dynamics.

Padding conventions (see package docstring for the full layout):

  * per-interval arrival rows are padded to ``max_arrivals`` with
    ``arr_valid`` masks;
  * per-task fragment columns are padded to ``max_frags``; padding
    fragments are born ``done=True`` with ``worker=-1`` so every physics
    mask excludes them for free.

RNG decoupling: a live ``EdgeSim`` interleaves arrival draws with
per-completion accuracy draws on one ``RandomState`` stream, so its
stream position depends on the policy under test.  ``compile_trace``
decouples them — accuracy noise is sampled at realization time — which
makes the workload policy-independent (the same trace can be replayed
through ``EdgeSim`` *and* the jitted backend; see
``repro.env.jaxsim.reference``).  The marginal distribution is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.env.cluster import Cluster, make_cluster
from repro.env.mobility import MobilityModel
from repro.env.workload import WorkloadGenerator


@dataclasses.dataclass
class ClusterArrays:
    """Per-worker constants the kernels consume (all float64/(n,))."""
    mips: np.ndarray
    ram: np.ndarray
    net_bw: np.ndarray
    power_idle: np.ndarray
    power_peak: np.ndarray
    cost_hr: np.ndarray

    @property
    def n(self) -> int:
        return len(self.mips)

    @classmethod
    def from_cluster(cls, cluster: Cluster) -> "ClusterArrays":
        return cls(mips=cluster.mips(), ram=cluster.ram(),
                   net_bw=cluster.net_bw(),
                   power_idle=np.array([t.power_idle for t in cluster.types],
                                       np.float64),
                   power_peak=np.array([t.power_peak for t in cluster.types],
                                       np.float64),
                   cost_hr=cluster.cost_hr())

    def as_dict(self):
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}


@dataclasses.dataclass
class TraceArrays:
    """One compiled (seed, λ) trace.

    Shapes: T = n_intervals, A = max arrivals per interval, F = max
    fragments per task, n = workers.  ``arr_*`` rows beyond
    ``arr_valid`` are padding; fragment columns ``>= arr_nfrag`` are
    padding.
    """
    lam: float
    seed: int
    interval_s: float
    substeps: int

    bw_mult: np.ndarray        # (T, n) mobility bandwidth multipliers
    arr_valid: np.ndarray      # (T, A) bool
    arr_id: np.ndarray         # (T, A) int64  globally unique task id
    arr_app: np.ndarray        # (T, A) int32
    arr_batch: np.ndarray      # (T, A) int64
    arr_sla: np.ndarray        # (T, A) float64
    arr_arrival_s: np.ndarray  # (T, A) float64 (== sim clock at admission)
    arr_acc: np.ndarray        # (T, A) float64 pre-sampled accuracy
    arr_decision: np.ndarray   # (T, A) int32
    arr_chain: np.ndarray      # (T, A) bool
    arr_nfrag: np.ndarray      # (T, A) int32
    frag_instr: np.ndarray     # (T, A, F) float64
    frag_ram: np.ndarray       # (T, A, F) float64
    frag_out: np.ndarray       # (T, A, F) float64

    @property
    def n_intervals(self) -> int:
        return self.arr_valid.shape[0]

    @property
    def max_arrivals(self) -> int:
        return self.arr_valid.shape[1]

    @property
    def max_frags(self) -> int:
        return self.frag_instr.shape[2]

    @property
    def n_tasks(self) -> int:
        return int(self.arr_valid.sum())

    def kernel_dict(self):
        """The leaves the jitted kernel consumes (metric-relevant only)."""
        return {"bw_mult": self.bw_mult, "valid": self.arr_valid,
                "sla": self.arr_sla, "arrival_s": self.arr_arrival_s,
                "app": self.arr_app, "batch": self.arr_batch,
                "acc": self.arr_acc, "decision": self.arr_decision,
                "chain": self.arr_chain, "nfrag": self.arr_nfrag,
                "instr": self.frag_instr, "ram": self.frag_ram,
                "out_bytes": self.frag_out}


def compile_trace(decider, lam: float = 6.0, seed: int = 0,
                  n_intervals: int = 100, interval_s: float = 300.0,
                  substeps: int = 30, apps: Optional[Sequence[int]] = None,
                  cluster: Optional[Cluster] = None,
                  max_arrivals: Optional[int] = None) -> TraceArrays:
    """Compile one trace: Poisson arrivals + split decisions + realized
    fragments + mobility, as dense padded arrays.

    ``decider`` is a host-side static decider: ``decide(tasks) ->
    List[int]`` (``repro.env.jaxsim.policies``).  The simulation clock is
    replicated by accumulating ``dt`` per substep exactly as the interval
    kernels do, so ``arr_arrival_s`` carries bit-identical timestamps.
    """
    cluster = cluster or make_cluster()
    gen = WorkloadGenerator(lam=lam, seed=seed, apps=apps)
    mob = MobilityModel(cluster.n, cluster.mobile_mask(), seed=seed + 1)
    dt = interval_s / substeps

    per_interval: List[list] = []
    bw_rows = []
    now = 0.0
    for _ in range(n_intervals):
        tasks = gen.arrivals(now)
        decisions = decider.decide(tasks)
        rows = []
        for task, d in zip(tasks, decisions):
            gen.realize(task, int(d))
            rams = {f.ram_mb for f in task.fragments}
            if len(rams) > 1:
                # the kernels' per-task RAM census (ram_task @ cnt) relies
                # on realize() giving every fragment of a task the same
                # footprint — fail loudly if a future workload breaks that
                raise ValueError(
                    "jaxsim requires a uniform per-task fragment RAM "
                    f"footprint; task {task.id} has {sorted(rams)}")
            acc = gen.accuracy_of(task)
            rows.append((task, acc))
        per_interval.append(rows)
        _, bw = mob.step()
        bw_rows.append(bw)
        for _ in range(substeps):
            now += dt

    T = n_intervals
    A = max_arrivals if max_arrivals is not None \
        else max(1, max(len(r) for r in per_interval))
    F = max([1] + [len(t.fragments) for r in per_interval for t, _ in r])
    if max(len(r) for r in per_interval) > A:
        raise ValueError(
            f"max_arrivals={A} < observed {max(len(r) for r in per_interval)}")

    tr = TraceArrays(
        lam=lam, seed=seed, interval_s=interval_s, substeps=substeps,
        bw_mult=np.stack(bw_rows),
        arr_valid=np.zeros((T, A), bool),
        arr_id=np.zeros((T, A), np.int64),
        arr_app=np.zeros((T, A), np.int32),
        arr_batch=np.zeros((T, A), np.int64),
        arr_sla=np.zeros((T, A), np.float64),
        arr_arrival_s=np.zeros((T, A), np.float64),
        arr_acc=np.zeros((T, A), np.float64),
        arr_decision=np.full((T, A), -1, np.int32),
        arr_chain=np.zeros((T, A), bool),
        arr_nfrag=np.zeros((T, A), np.int32),
        frag_instr=np.zeros((T, A, F), np.float64),
        frag_ram=np.zeros((T, A, F), np.float64),
        frag_out=np.zeros((T, A, F), np.float64))

    for t, rows in enumerate(per_interval):
        for a, (task, acc) in enumerate(rows):
            tr.arr_valid[t, a] = True
            tr.arr_id[t, a] = task.id
            tr.arr_app[t, a] = task.app
            tr.arr_batch[t, a] = task.batch
            tr.arr_sla[t, a] = task.sla_s
            tr.arr_arrival_s[t, a] = task.arrival_s
            tr.arr_acc[t, a] = acc
            tr.arr_decision[t, a] = task.decision
            tr.arr_chain[t, a] = task.chain
            tr.arr_nfrag[t, a] = len(task.fragments)
            for i, f in enumerate(task.fragments):
                tr.frag_instr[t, a, i] = f.instr_left
                tr.frag_ram[t, a, i] = f.ram_mb
                tr.frag_out[t, a, i] = f.out_bytes
    return tr


@dataclasses.dataclass
class DualTraceArrays:
    """One compiled (seed, λ) trace with BOTH split variants realized.

    The in-kernel deciders pick their split arm *inside* the jitted
    interval loop, so split decisions can no longer be realized at
    trace-compile time.  Instead every task carries both realizations
    side by side (variant axis V=2, ordered by ``variants`` — [LAYER,
    SEMANTIC] for the SplitPlace MAB, [LAYER, COMPRESSED] for the Gillis
    baseline) and the kernel selects per-arrival rows by the in-kernel
    decision mask (``kernels.select_variant``).  Shared per-task data
    (SLA, arrival clock, app, batch) is variant-independent;
    accuracy/fragments/chain flags are per-variant.  ``lat_prev[t]`` is
    the mobility latency multiplier visible to the placer at interval
    ``t`` (the host placer sees the *previous* interval's mobility draw;
    row 0 is all-ones).
    """
    lam: float
    seed: int
    interval_s: float
    substeps: int

    bw_mult: np.ndarray        # (T, n)
    lat_prev: np.ndarray       # (T, n) placement-time latency multipliers
    arr_valid: np.ndarray      # (T, A) bool
    arr_id: np.ndarray         # (T, A) int64
    arr_app: np.ndarray        # (T, A) int32
    arr_batch: np.ndarray      # (T, A) int64
    arr_sla: np.ndarray        # (T, A) float64
    arr_arrival_s: np.ndarray  # (T, A) float64
    var_acc: np.ndarray        # (T, A, V) float64
    var_chain: np.ndarray      # (T, A, V) bool
    var_nfrag: np.ndarray      # (T, A, V) int32
    var_instr: np.ndarray      # (T, A, V, F) float64
    var_ram: np.ndarray        # (T, A, V, F) float64
    var_out: np.ndarray        # (T, A, V, F) float64
    variants: tuple = (0, 1)   # decision codes realized on the V axis

    @property
    def n_intervals(self) -> int:
        return self.arr_valid.shape[0]

    @property
    def max_arrivals(self) -> int:
        return self.arr_valid.shape[1]

    @property
    def max_frags(self) -> int:
        return self.var_instr.shape[3]

    @property
    def n_tasks(self) -> int:
        return int(self.arr_valid.sum())

    def kernel_dict(self):
        return {"bw_mult": self.bw_mult, "lat_prev": self.lat_prev,
                "valid": self.arr_valid, "sla": self.arr_sla,
                "arrival_s": self.arr_arrival_s, "app": self.arr_app,
                "batch": self.arr_batch, "vacc": self.var_acc,
                "vchain": self.var_chain, "vnfrag": self.var_nfrag,
                "vinstr": self.var_instr, "vram": self.var_ram,
                "vout": self.var_out}


def compile_trace_dual(lam: float = 6.0, seed: int = 0,
                       n_intervals: int = 100, interval_s: float = 300.0,
                       substeps: int = 30, apps: Optional[Sequence[int]] = None,
                       cluster: Optional[Cluster] = None,
                       max_arrivals: Optional[int] = None,
                       variants: Sequence[int] = None) -> DualTraceArrays:
    """Compile one trace with both split variants realized per task, for
    the in-kernel learned deciders.  ``variants`` names the two decision
    codes of the V axis — (LAYER, SEMANTIC) by default (the SplitPlace
    MAB's arms); the Gillis baseline compiles (LAYER, COMPRESSED).

    The RNG choreography matches ``compile_trace`` draw for draw (one
    image-size uniform + one accuracy-noise normal per task), so arrivals
    and SLAs are identical to the single-variant compile of the same
    seed; the container image is drawn once and shared by both variants,
    and the accuracy noise shifts each variant's base accuracy
    (``workload.accuracy_from_noise``).
    """
    from repro.env.workload import (APP_PROFILES, LAYER, SEMANTIC,
                                    accuracy_from_noise)

    variant_codes = tuple(variants) if variants is not None \
        else (LAYER, SEMANTIC)
    if len(variant_codes) != 2:
        raise ValueError(f"exactly two variants required, got "
                         f"{variant_codes}")
    cluster = cluster or make_cluster()
    gen = WorkloadGenerator(lam=lam, seed=seed, apps=apps)
    mob = MobilityModel(cluster.n, cluster.mobile_mask(), seed=seed + 1)
    dt = interval_s / substeps

    per_interval: List[list] = []
    bw_rows, lat_rows = [], []
    now = 0.0
    for _ in range(n_intervals):
        tasks = gen.arrivals(now)
        rows = []
        for task in tasks:
            img_mb = gen.rng.uniform(*APP_PROFILES[task.app].model_mb)
            variants_r = []
            for d in variant_codes:
                gen.realize(task, d, img_mb=img_mb)
                rams = {f.ram_mb for f in task.fragments}
                if len(rams) > 1:
                    raise ValueError(
                        "jaxsim requires a uniform per-task fragment RAM "
                        f"footprint; task {task.id} has {sorted(rams)}")
                variants_r.append((task.chain,
                                   [(f.instr_left, f.ram_mb, f.out_bytes)
                                    for f in task.fragments]))
            noise = gen.rng.normal(0, 0.003)
            accs = [accuracy_from_noise(task.app, d, noise)
                    for d in variant_codes]
            rows.append((task, variants_r, accs))
        per_interval.append(rows)
        lat, bw = mob.step()
        bw_rows.append(bw)
        lat_rows.append(lat)
        for _ in range(substeps):
            now += dt

    T = n_intervals
    A = max_arrivals if max_arrivals is not None \
        else max(1, max(len(r) for r in per_interval))
    if max(len(r) for r in per_interval) > A:
        raise ValueError(
            f"max_arrivals={A} < observed {max(len(r) for r in per_interval)}")
    F = max([1] + [len(frags) for r in per_interval
                   for _, vr, _ in r for _, frags in vr])

    tr = DualTraceArrays(
        lam=lam, seed=seed, interval_s=interval_s, substeps=substeps,
        variants=variant_codes,
        bw_mult=np.stack(bw_rows),
        lat_prev=np.vstack([np.ones((1, cluster.n)),
                            np.stack(lat_rows)[:-1]]) if T else
        np.ones((0, cluster.n)),
        arr_valid=np.zeros((T, A), bool),
        arr_id=np.zeros((T, A), np.int64),
        arr_app=np.zeros((T, A), np.int32),
        arr_batch=np.zeros((T, A), np.int64),
        arr_sla=np.zeros((T, A), np.float64),
        arr_arrival_s=np.zeros((T, A), np.float64),
        var_acc=np.zeros((T, A, 2), np.float64),
        var_chain=np.zeros((T, A, 2), bool),
        var_nfrag=np.zeros((T, A, 2), np.int32),
        var_instr=np.zeros((T, A, 2, F), np.float64),
        var_ram=np.zeros((T, A, 2, F), np.float64),
        var_out=np.zeros((T, A, 2, F), np.float64))

    for t, rows in enumerate(per_interval):
        for a, (task, variants_r, accs) in enumerate(rows):
            tr.arr_valid[t, a] = True
            tr.arr_id[t, a] = task.id
            tr.arr_app[t, a] = task.app
            tr.arr_batch[t, a] = task.batch
            tr.arr_sla[t, a] = task.sla_s
            tr.arr_arrival_s[t, a] = task.arrival_s
            for v, (chain, frags) in enumerate(variants_r):
                tr.var_acc[t, a, v] = accs[v]
                tr.var_chain[t, a, v] = chain
                tr.var_nfrag[t, a, v] = len(frags)
                for i, (instr, ram, out) in enumerate(frags):
                    tr.var_instr[t, a, v, i] = instr
                    tr.var_ram[t, a, v, i] = ram
                    tr.var_out[t, a, v, i] = out
    return tr


#: per-leaf pad axes: leaves keyed here pad their arrival axis to A and
#: (fragment leaves) their trailing fragment axis to F; per-worker leaves
#: (bw_mult / lat_prev) are never padded
_NO_PAD_KEYS = ("bw_mult", "lat_prev")
_FRAG_PAD_KEYS = ("instr", "ram", "out_bytes", "vinstr", "vram", "vout")


def stack_traces(traces: Sequence[TraceArrays], max_arrivals: int = 0,
                 max_frags: int = 0) -> dict:
    """Stack per-cell traces into one batched kernel-input pytree.

    Works for both ``TraceArrays`` and ``DualTraceArrays`` grids (never
    mixed).  Harmonizes the A (arrivals) and F (fragments) pads to the
    grid-wide maxima (or the explicit overrides, so separately stacked
    chunks of one grid share compiled executables); every leaf gains a
    leading grid axis for ``vmap``.
    """
    if not traces:
        raise ValueError("empty grid")
    t0 = traces[0]

    def sig(t):
        return (t.n_intervals, t.interval_s, t.substeps,
                getattr(t, "variants", None))

    bad = [(i, sig(t)) for i, t in enumerate(traces) if sig(t) != sig(t0)]
    if bad:
        lines = "; ".join(
            f"trace[{i}] has (n_intervals, interval_s, substeps, "
            f"variants)={s}" for i, s in bad)
        raise ValueError(
            "grid cells must share n_intervals/interval_s/substeps/"
            "variants (shapes and decision codes are compile-time "
            f"static): trace[0] has {sig(t0)}, but {lines}")
    A = max([max_arrivals] + [t.max_arrivals for t in traces])
    F = max([max_frags] + [t.max_frags for t in traces])

    def pad(x, axis, to):
        w = [(0, 0)] * x.ndim
        w[axis] = (0, to - x.shape[axis])
        return np.pad(x, w)

    leaves = []
    for t in traces:
        d = t.kernel_dict()
        out = {}
        for k, v in d.items():
            if k in _NO_PAD_KEYS:
                out[k] = v
                continue
            v = pad(v, 1, A)
            if k in _FRAG_PAD_KEYS:
                v = pad(v, v.ndim - 1, F)
            out[k] = v
        leaves.append(out)
    return {k: np.stack([lv[k] for lv in leaves]) for k in leaves[0]}


def chunk_tapes(trace, chunk_intervals: int):
    """Slice one compiled trace's kernel leaves into chunk-local tapes
    for the streaming replay driver (``repro.env.jaxsim.stream``).

    Yields ``(t0, leaves)`` pairs where ``t0`` is the chunk's absolute
    start interval and every leaf holds rows ``[t0, t0+chunk)`` of the
    corresponding ``kernel_dict`` array (all leaves are T-leading, so a
    plain slice works for single-variant and dual traces alike).  The
    final chunk is shorter when ``n_intervals`` is not a multiple —
    which costs one extra compile for the remainder shape; pick a
    dividing ``chunk_intervals`` when that matters."""
    if chunk_intervals < 1:
        raise ValueError(f"chunk_intervals must be >= 1, "
                         f"got {chunk_intervals}")
    d = trace.kernel_dict()
    for t0 in range(0, trace.n_intervals, chunk_intervals):
        yield t0, {k: v[t0:t0 + chunk_intervals] for k, v in d.items()}


def default_capacity(traces: Sequence[TraceArrays]) -> int:
    """Default ``max_active`` slot capacity for a grid: enough for every
    task of the densest trace to be live at once (never drops), rounded
    up a little so nearby grids share one compiled executable."""
    need = max(max(t.n_tasks for t in traces), 16)
    return int(-(-need // 32) * 32)
