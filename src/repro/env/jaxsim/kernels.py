"""Jit-able interval kernels over the fixed-capacity slot arrays.

Three pieces, mirroring one ``EdgeSim`` interval:

  * ``admit``       — scatter this interval's (padded) arrivals into free
                      task slots;
  * ``place``       — vectorized BestFit for unplaced fragments + the
                      RAM feasibility repair of ``EdgeSim.apply_placement``,
                      both as ``lax.fori_loop`` sequential greedy passes in
                      admission order (the greedy admit order is part of
                      the physics contract, so it cannot be parallelized —
                      but under ``vmap`` the whole grid shares each loop
                      iteration, which is where the batching win comes
                      from);
  * ``run_substeps``— the substep physics of ``repro.env.soa.run_interval``
                      (MIPS sharing, swap slowdown, chain activation
                      transfers under mobility-modulated NIC bandwidth,
                      eqs. 13–16 accumulators) on dense ``(K, F)`` arrays.

Every elementwise float op matches ``env/soa.py`` in float64; only
reduction orders/groupings differ (one-hot matmul and count-matrix
censuses vs sequential ``bincount``), which is why the cross-backend
contract is ``allclose`` on summary metrics rather than the SoA↔legacy
bit-exactness.

Unsupported relative to the host repair: the ``w < 0 → argmin`` rescue in
``apply_placement`` is unreachable here (every live unplaced fragment
receives a BestFit target in the same interval), so it is omitted.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import daso as daso_mod
from repro.core import mab as mab_mod
from repro.env.soa import NIC_CAP_MB

_SEQ_DEAD = jnp.iinfo(jnp.int64).max


def init_state(K: int, F: int, n: int):
    """Empty slot store: all slots free, padding-done, worker −1."""
    f8 = jnp.float64
    return {
        # per-fragment (K, F)
        "instr": jnp.zeros((K, F), f8),
        "ram": jnp.zeros((K, F), f8),
        "out_bytes": jnp.zeros((K, F), f8),
        "worker": jnp.full((K, F), -1, jnp.int32),
        "done": jnp.ones((K, F), bool),
        "transfer": jnp.zeros((K, F), f8),
        # per-task (K,)
        "nfrag": jnp.zeros((K,), jnp.int32),
        "chain": jnp.zeros((K,), bool),
        "stage": jnp.zeros((K,), jnp.int32),
        "placed": jnp.zeros((K,), bool),
        "alive": jnp.zeros((K,), bool),
        "task_done": jnp.ones((K,), bool),
        "sla": jnp.zeros((K,), f8),
        "arrival_s": jnp.zeros((K,), f8),
        "wait_s": jnp.zeros((K,), f8),
        "acc": jnp.zeros((K,), f8),
        "decision": jnp.zeros((K,), jnp.int32),
        # learned-policy feedback channels: app/batch identify the MAB
        # context of a slot, resp records its response time at the substep
        # it finished (batch is 1.0 on dead slots so norms never divide
        # by zero)
        "app": jnp.zeros((K,), jnp.int32),
        "batch": jnp.ones((K,), f8),
        "resp": jnp.zeros((K,), f8),
        "seq": jnp.full((K,), _SEQ_DEAD, jnp.int64),
        "seq_counter": jnp.zeros((), jnp.int64),
        "dropped": jnp.zeros((), jnp.int64),
    }


def admit(state, arr):
    """Scatter the interval's arrival rows into free slots.

    ``arr`` holds one interval's slices of the compiled trace (leading
    axis A).  Valid arrivals are a prefix; arrival *j* takes the *j*-th
    free slot (slot identity is irrelevant to the physics — admission
    *order* is preserved via ``seq``).  Arrivals beyond capacity are
    dropped and counted, never silently lost.
    """
    K, F = state["worker"].shape
    A = arr["valid"].shape[0]
    # j-th free slot via binary search on the running free count (cheaper
    # than `nonzero`, which XLA:CPU lowers to a per-row scatter)
    fcum = jnp.cumsum((~state["alive"]).astype(jnp.int32))
    slots = jnp.searchsorted(fcum, jnp.arange(1, A + 1), side="left")
    slots = jnp.where(slots >= K, K, slots)
    valid = arr["valid"]
    tgt = jnp.where(valid, slots, K)          # K == out-of-bounds → drop
    s = dict(state)
    s["dropped"] = state["dropped"] + jnp.sum(valid & (tgt >= K))

    fcols = jnp.arange(F, dtype=jnp.int32)[None, :]
    pad_done = fcols >= arr["nfrag"][:, None]
    st = lambda name, val: s[name].at[tgt].set(val, mode="drop")
    s["instr"] = st("instr", arr["instr"])
    s["ram"] = st("ram", arr["ram"])
    s["out_bytes"] = st("out_bytes", arr["out_bytes"])
    s["worker"] = st("worker", jnp.full((A, F), -1, jnp.int32))
    s["done"] = st("done", pad_done)
    s["transfer"] = st("transfer", jnp.zeros((A, F)))
    s["nfrag"] = st("nfrag", arr["nfrag"])
    s["chain"] = st("chain", arr["chain"])
    s["stage"] = st("stage", jnp.zeros((A,), jnp.int32))
    s["placed"] = st("placed", jnp.zeros((A,), bool))
    s["alive"] = st("alive", jnp.ones((A,), bool))
    s["task_done"] = st("task_done", jnp.zeros((A,), bool))
    s["sla"] = st("sla", arr["sla"])
    s["arrival_s"] = st("arrival_s", arr["arrival_s"])
    s["wait_s"] = st("wait_s", jnp.zeros((A,)))
    s["acc"] = st("acc", arr["acc"])
    s["decision"] = st("decision", arr["decision"])
    s["app"] = st("app", arr["app"])
    s["batch"] = st("batch", jnp.maximum(
        arr["batch"].astype(jnp.float64), 1.0))
    s["resp"] = st("resp", jnp.zeros((A,)))
    s["seq"] = st("seq", state["seq_counter"]
                  + jnp.arange(A, dtype=jnp.int64))
    s["seq_counter"] = state["seq_counter"] + jnp.sum(valid)
    return s


def _admission_order(state):
    """Slot indices sorted by admission sequence (dead slots last)."""
    return jnp.argsort(jnp.where(state["alive"], state["seq"], _SEQ_DEAD))


def _onehot(idx, n, dtype=jnp.float64):
    """(…, n) one-hot.  XLA:CPU scatter (what ``segment_sum`` lowers to)
    costs ~µs *per update row*, so the hot kernels do their per-worker
    censuses as one-hot matmuls instead — dense FLOPs on (K·F, n) tiles
    are orders of magnitude cheaper here.  Integer counts use float32
    one-hots (exact below 2²⁴ and half the memory traffic); anything
    entering float64 physics sums stays float64."""
    return (idx[..., None] == jnp.arange(n)).astype(dtype)


def bestfit_requests(state, cl):
    """Phase A: greedy BestFit worker requests for unplaced fragments —
    semantics-equal to ``BestFitPlacer.place`` (already-placed fragments
    keep their current worker in the returned request matrix).

    Cost shaping (the greedy admit order is part of the physics contract,
    so the loop cannot be parallelized — but its *trip count* can
    shrink): the scan walks only the compacted admission-ordered list of
    fragments that need a worker (``n_new`` iterations, not ``K·F``);
    positions come from one vectorized binary search over the running
    count (XLA:CPU lowers `nonzero` to a ~ms scatter; this is
    ~log₂(K·F) fused gather rounds).  Under ``vmap`` every grid cell
    shares each iteration.
    """
    K, F = state["worker"].shape
    n = cl["ram"].shape[0]
    cap, mips = cl["ram"], cl["mips"]
    worker, done, ram = state["worker"], state["done"], state["ram"]
    wsafe = jnp.clip(worker, 0, n - 1)
    live = (~done) & (worker >= 0)
    # census via the f32 fragment-count einsum + per-task RAM (fragments
    # of one task share one footprint; see run_substeps docstring)
    kfn32 = _onehot(wsafe, n, jnp.float32)
    cnt_live = jnp.einsum("kf,kfn->kn", live.astype(jnp.float32), kfn32)
    ram_task = ram[:, 0]
    lr0 = jnp.stack([jnp.ones((K,)), ram_task]) @ cnt_live.astype(jnp.float64)
    load0, ram_used0 = lr0[0], lr0[1]
    static = 0.3 * mips / mips.max()
    order = _admission_order(state)
    arange_n = jnp.arange(n)

    new_mask = (~done) & (worker < 0)
    flat_ord = new_mask[order].ravel()
    ncum = jnp.cumsum(flat_ord.astype(jnp.int32))
    n_new = ncum[-1]
    pos = jnp.minimum(jnp.searchsorted(
        ncum, jnp.arange(1, K * F + 1, dtype=jnp.int32), side="left"),
        K * F - 1)
    slot_of = order[pos // F]
    f_of = (pos % F).astype(jnp.int32)

    def bodyA(i, carry):
        req, ram_free, load, score = carry
        slot, f = slot_of[i], f_of[i]
        rm = ram[slot, f]
        buf = jnp.where(ram_free < rm, -1e9, score)
        w = jnp.argmax(buf)
        hot = arange_n == w
        nf = ram_free[w] - rm
        nl = load[w] + 1.0
        ns = -nl + static[w] + 0.1 * nf / cap[w]
        req = req.at[slot, f].set(w.astype(jnp.int32))
        ram_free = jnp.where(hot, nf, ram_free)
        load = jnp.where(hot, nl, load)
        score = jnp.where(hot, ns, score)
        return req, ram_free, load, score

    score0 = -load0 + static + 0.1 * (cap - ram_used0) / cap
    req, _, _, _ = lax.fori_loop(
        0, n_new, bodyA, (worker, cap - ram_used0, load0, score0))
    return req


def apply_requests(state, cl, req):
    """Phase B: the RAM feasibility repair of ``EdgeSim.apply_placement``
    over an arbitrary worker-request matrix ``req`` (K, F).

    Fast path: when every requested placement fits its worker outright,
    the sequential repair provably admits everything verbatim (RAM
    prefix sums are bounded by the final totals), so its loop runs zero
    iterations.  Requests must cover every live unplaced fragment with a
    valid worker index (BestFit and the array-form DASO stage both
    guarantee this), which keeps the host repair's ``w < 0 → argmin``
    rescue unreachable.
    """
    K, F = state["worker"].shape
    n = cl["ram"].shape[0]
    cap = cl["ram"]
    worker, done, ram = state["worker"], state["done"], state["ram"]
    ram_task = ram[:, 0]
    order = _admission_order(state)
    alive, chain, stage = state["alive"], state["chain"], state["stage"]

    # fast path: when every requested placement fits its worker outright,
    # the sequential repair is the identity on the requests
    live_und = ~done                     # dead/padding columns are done
    holds_f = jnp.where(chain[:, None],
                        jnp.arange(F, dtype=jnp.int32)[None, :]
                        == stage[:, None], True)
    req_safe = jnp.clip(req, 0, n - 1)
    cnt_dem = jnp.einsum("kf,kfn->kn",
                         (live_und & holds_f).astype(jnp.float32),
                         _onehot(req_safe, n, jnp.float32))
    demand = ram_task @ cnt_dem.astype(jnp.float64)
    feasible = jnp.all(demand <= cap)
    worker_fast = jnp.where(live_und, req, worker)
    placed_fast = state["placed"] | alive

    def bodyB(i, carry):
        ram_used, worker2, placed = carry
        slot = order[i]
        pb = alive[slot]
        ok = jnp.bool_(True)
        for f in range(F):
            act = pb & (~done[slot, f]) & ok
            holds = (~chain[slot]) | (f == stage[slot])
            w = jnp.clip(req[slot, f], 0, n - 1)
            rm = ram[slot, f]
            infeas = act & holds & (ram_used[w] + rm > cap[w])
            headroom = cap - ram_used
            cand = jnp.argmax(headroom).astype(jnp.int32)
            fb_ok = headroom[cand] >= rm
            w2 = jnp.where(infeas & fb_ok, cand, w)
            admit_f = act & (~infeas | fb_ok)
            ok = ok & ~(infeas & ~fb_ok)
            worker2 = worker2.at[slot, f].set(
                jnp.where(admit_f, w2, worker2[slot, f]))
            ram_used = ram_used.at[w2].add(
                jnp.where(admit_f & holds, rm, 0.0))
        fail = pb & ~ok
        worker2 = worker2.at[slot].set(
            jnp.where(fail, jnp.full((F,), -1, jnp.int32), worker2[slot]))
        placed = placed.at[slot].set(jnp.where(pb, ok, placed[slot]))
        return ram_used, worker2, placed

    n_alive = jnp.sum(alive)
    trip = jnp.where(feasible, 0, n_alive)
    _, worker2, placed = lax.fori_loop(
        0, trip, bodyB, (jnp.zeros((n,)), worker_fast, placed_fast))
    s = dict(state)
    s["worker"] = worker2
    s["placed"] = placed
    return s


def place(state, cl):
    """BestFit targets for unplaced fragments, then the feasibility
    repair — semantics-equal to ``BestFitPlacer.place`` +
    ``EdgeSim.apply_placement``.  Learned placers reuse the same two
    stages with a policy step in between (``daso_requests``)."""
    return apply_requests(state, cl, bestfit_requests(state, cl))


def _run_substeps_fused(state, acc, bw_mult, cl, *, substeps: int,
                        dt: float, swap_slowdown: float, impl: str):
    """Route one interval of substep physics through the fused kernels
    under ``src/repro/kernels/`` — ``impl="pallas"`` is the Pallas
    edge-substep kernel (interpret mode on CPU), ``impl="ref"`` its
    pure-jnp oracle.  Both consume/produce the same carry slices as the
    inline XLA path below; ``ram`` collapses to its per-task column
    (fragments of one task share one RAM footprint by construction)."""
    if impl == "pallas":
        from repro.kernels.edge_substep import edge_substep as fn
    elif impl == "ref":
        from repro.kernels.ref import edge_substep_ref as fn
    else:
        raise ValueError(f"unknown substep impl {impl!r} "
                         "(want 'xla', 'pallas' or 'ref')")
    (instr, done, transfer, stage, task_done, resp, now, metrics, busy,
     pwt_delta) = fn(
        state["instr"], state["done"], state["transfer"], state["stage"],
        state["task_done"], state["resp"], acc["now"][None],
        acc["metrics"], state["worker"], state["ram"][:, 0],
        state["out_bytes"], state["nfrag"], state["chain"],
        state["placed"], state["sla"], state["arrival_s"], state["acc"],
        state["wait_s"], state["decision"], bw_mult, cl["mips"],
        cl["ram"], cl["net_bw"], substeps=substeps, dt=dt,
        swap_slowdown=swap_slowdown, nic_cap=NIC_CAP_MB)
    s = dict(state)
    s.update(instr=instr, done=done, transfer=transfer, stage=stage,
             task_done=task_done, resp=resp)
    a = dict(acc)
    a.update(now=now[0], pwt=acc["pwt"] + pwt_delta, metrics=metrics)
    return s, a, busy


def run_substeps(state, acc, bw_mult, cl, *, substeps: int, dt: float,
                 swap_slowdown: float, impl: str = "xla"):
    """One interval of substep physics; returns (state, acc, busy_time).

    ``impl`` selects the execution strategy: ``"xla"`` (default) is the
    inline incremental-census formulation below, tuned op by op for
    XLA:CPU; ``"pallas"`` routes through the fused
    ``repro.kernels.edge_substep`` kernel (one VMEM-resident loop,
    interpret mode on CPU) and ``"ref"`` through its pure-jnp oracle —
    all three agree to float64 rounding (the fuzzed parity suite and
    the differential/golden fences pin it).

    Mask structure and op order follow ``soa.run_interval``: the
    placed/chain masks are interval-static, ``done``/``transfer``/
    ``stage`` evolve per substep, execution precedes transfers, and the
    clock advances by repeated ``+= dt`` so finish timestamps carry the
    same accumulated rounding.

    Census cost shaping — a per-substep (K·F, n) float64 census whose
    operand depends on the loop carry is an un-hoistable dot XLA:CPU runs
    slowly every substep.  Instead the kernel carries ``cnt``, the
    per-(task, worker) count of undone placed fragments of *non-chain*
    tasks (float32 — exact, these are small integers), updated
    incrementally from each substep's completions.  Then

      * non-chain load = column sum of ``cnt``;
      * non-chain RAM  = ``ram_task @ cnt`` — fragments of one task share
        one RAM footprint by construction (``compile_trace`` asserts it);
      * chain load/RAM = a (K, n) one-hot census of each chain's single
        active-stage fragment;

    and the only full-width per-substep contraction left is the float32
    completion-delta reduce, exact for counts.
    """
    if impl != "xla":
        return _run_substeps_fused(state, acc, bw_mult, cl,
                                   substeps=substeps, dt=dt,
                                   swap_slowdown=swap_slowdown, impl=impl)
    K, F = state["worker"].shape
    n = cl["ram"].shape[0]
    mips, cap, net_bw = cl["mips"], cl["ram"], cl["net_bw"]
    worker, ram, out_bytes = state["worker"], state["ram"], state["out_bytes"]
    nfrag, chain = state["nfrag"], state["chain"]
    sla, arrival, acc_t = state["sla"], state["arrival_s"], state["acc"]
    wait_s, decision = state["wait_s"], state["decision"]
    fidx = jnp.arange(F, dtype=jnp.int32)[None, :]
    wsafe = jnp.clip(worker, 0, n - 1)
    chain_f = chain[:, None]
    placed_f = state["placed"][:, None] & (worker >= 0)
    holdable = worker >= 0
    chactive = chain & state["placed"] & ~state["task_done"]
    # interval-static hoists: worker assignments cannot change mid-interval
    kfn32 = _onehot(wsafe, n, jnp.float32)               # (K, F, n)
    ram_task = ram[:, 0]                                 # uniform per task
    mips_f = mips[wsafe]
    doh = _onehot(jnp.clip(decision, 0, 2), 3)           # (K, 3)
    not_chain_f = ~chain_f
    arange_n = jnp.arange(n)
    ones_k = jnp.ones((K,))
    dual_idx = jnp.concatenate([wsafe.ravel(), wsafe.ravel() + n])
    hand_static = chain_f & (fidx < nfrag[:, None] - 1)
    out_r = jnp.concatenate(                              # shifted handoffs
        [jnp.zeros((K, 1)), out_bytes[:, :-1]], axis=1)
    # bandwidth between consecutive chain stages is also interval-static
    # (workers + mobility fixed): bw_pair[k, f] = rate into fragment f
    w_prev = jnp.clip(jnp.roll(worker, 1, axis=1), 0, n - 1)
    bw_pair = jnp.minimum(NIC_CAP_MB,
                          jnp.minimum(net_bw[w_prev] / 100.0,
                                      net_bw[wsafe] / 100.0))
    bw_pair = bw_pair * jnp.minimum(bw_mult[w_prev], bw_mult[wsafe])

    def census(mask_f):
        """Per-(task, worker) fragment counts of a (K, F) bool mask.
        (einsum, NOT broadcast-multiply+reduce: XLA:CPU runs the latter
        ~7× slower on these shapes.)"""
        return jnp.einsum("kf,kfn->kn", mask_f.astype(jnp.float32), kfn32)

    cnt0 = census((~state["done"]) & holdable & not_chain_f)

    def body(carry, _):
        (instr, done, transfer, stage, task_done, now, busy, cnt,
         m, resp_rec) = carry
        notdone = ~done
        is_stage = fidx == stage[:, None]
        tle = (transfer <= 0.0) & is_stage
        runnable = (not_chain_f | tle) & placed_f & notdone
        holds = (not_chain_f | is_stage) & holdable & notdone
        # one packed gather pulls every per-active-stage channel (scalar
        # reductions cost ~18µs *each* in this vmapped loop on XLA:CPU)
        stage_ch = jnp.take_along_axis(
            jnp.stack([wsafe.astype(jnp.float64), transfer, bw_pair,
                       runnable.astype(jnp.float64),
                       holds.astype(jnp.float64)]),
            stage[None, :, None].astype(jnp.int32), axis=2)[:, :, 0]
        w_stage = stage_ch[0].astype(jnp.int32)
        cur_tl, bw_s = stage_ch[1], stage_ch[2]
        r_ch = (stage_ch[3] > 0.5) & chain
        h_ch = (stage_ch[4] > 0.5) & chain
        # per-worker census: non-chain tasks from the carried cnt matrix,
        # chains from their single active-stage fragment — all four
        # contractions packed as two dots
        ohs = w_stage[:, None] == arange_n               # (K, n)
        nc_lr = jnp.stack([ones_k, ram_task]) @ cnt.astype(jnp.float64)
        ch_lr = jnp.stack([r_ch.astype(jnp.float64),
                           jnp.where(h_ch, ram_task, 0.0)]) \
            @ ohs.astype(jnp.float64)
        load = nc_lr[0] + ch_lr[0]
        ram_load = nc_lr[1] + ch_lr[1]
        swap = ram_load > cap
        busy = busy + (load > 0) * dt
        lf_sw = jnp.take(jnp.concatenate([load, swap.astype(jnp.float64)]),
                         dual_idx).reshape(2, K, F)
        load_f, swap_f = lf_sw[0], lf_sw[1] > 0.5
        rate = mips_f / jnp.maximum(load_f, 1.0)
        rate = jnp.where(swap_f, rate * swap_slowdown, rate)
        instr = instr - jnp.where(runnable, rate * dt, 0.0)
        newly = runnable & (instr <= 0.0)
        done = done | newly
        cnt = cnt - census(newly & not_chain_f)
        # chain handoff: a finished stage queues its activation onto the
        # next fragment
        hand = newly & hand_static
        hand_r = jnp.concatenate(
            [jnp.zeros((K, 1), bool), hand[:, :-1]], axis=1)
        transfer = jnp.where(hand_r, out_r, transfer)
        # task completion → metric accumulators (eqs. 13–16 ingredients),
        # all nine summed by a single (K,)·(K, 9) dot into the m vector
        newfin = jnp.all(done, axis=1) & ~task_done
        task_done = task_done | newfin
        resp = now - arrival
        # response recorded at the finish substep — the learned-policy
        # feedback (MAB end_of_interval) consumes it after the interval
        resp_rec = jnp.where(newfin, resp, resp_rec)
        finf = newfin.astype(jnp.float64)
        mcols = jnp.stack(
            [ones_k, resp, (resp > sla).astype(jnp.float64), acc_t,
             ((resp <= sla) + acc_t) / 2.0, wait_s,
             doh[:, 0], doh[:, 1], doh[:, 2]], axis=1)
        m = m + finf @ mcols
        # transfers: forward the active stage's inbound activation
        s = stage
        cond = chactive & (s > 0) & (cur_tl > 0.0)
        transfer = transfer - jnp.where(
            cond, bw_s * 1e6 * dt, 0.0)[:, None] * is_stage
        # stage advance checks done[stage] *after* this substep's execution
        done_s = jnp.take_along_axis(done, s[:, None], axis=1)[:, 0]
        adv = chactive & done_s & (s < nfrag - 1)
        stage = stage + adv.astype(jnp.int32)
        now = now + dt
        return (instr, done, transfer, stage, task_done, now, busy, cnt,
                m, resp_rec), None

    carry = (state["instr"], state["done"], state["transfer"],
             state["stage"], state["task_done"], acc["now"],
             jnp.zeros((n,)), cnt0, acc["metrics"], state["resp"])
    (instr, done, transfer, stage, task_done, now, busy, _cnt,
     metrics, resp_rec), _ = lax.scan(body, carry, None, length=substeps,
                                      unroll=min(substeps, 2))
    # per-worker completion census once per interval: the accumulator only
    # ever consumes interval sums, and workers are interval-static, so
    # counting done-transitions at the end is exact
    completed = done & ~state["done"]
    pwt = acc["pwt"] + jnp.sum(census(completed),
                               axis=0).astype(jnp.float64)
    s = dict(state)
    s.update(instr=instr, done=done, transfer=transfer, stage=stage,
             task_done=task_done, resp=resp_rec)
    a = dict(acc)
    a.update(now=now, pwt=pwt, metrics=metrics)
    return s, a, busy


# -------------------------------------------------- learned-policy stages
#
# The stages below move the SplitPlace learning loop *inside* the jitted
# interval program: UCB split decisions over each interval's arrival rows
# (realized by selecting between the dual trace's pre-compiled variants),
# an array-form DASO placement pass between ``bestfit_requests`` and
# ``apply_requests``, and the Algorithm-1 MAB bookkeeping over the slots
# that finished the interval.  Every learned computation is a shared pure
# function from ``repro.core.{mab,daso}`` so the host-side parity replay
# (``reference.replay_trace_edgesim_learned``) runs the identical math.


def select_variant(shared, var, decision, arm_decisions=(0, 1)):
    """Realize the in-kernel split decisions against a dual trace.

    ``shared``/``var`` hold one interval's arrival rows of a
    ``DualTraceArrays`` (variant axis V=2); ``decision`` is the (A,) arm
    index per row.  ``arm_decisions`` maps the arm index to the decision
    *code* recorded on the task — (LAYER, SEMANTIC) for the SplitPlace
    MAB, (LAYER, COMPRESSED) for the Gillis baseline's dual traces.
    Returns the one-variant ``arr`` dict ``admit`` consumes.
    """
    d = decision.astype(jnp.int32)[:, None]

    def pick(x):
        idx = d if x.ndim == 2 else d[:, :, None]
        return jnp.take_along_axis(x, idx, axis=1)[:, 0]

    return {"valid": shared["valid"], "sla": shared["sla"],
            "arrival_s": shared["arrival_s"], "app": shared["app"],
            "batch": shared["batch"], "acc": pick(var["vacc"]),
            "chain": pick(var["vchain"]), "nfrag": pick(var["vnfrag"]),
            "instr": pick(var["vinstr"]), "ram": pick(var["vram"]),
            "out_bytes": pick(var["vout"]),
            "decision": jnp.asarray(arm_decisions, jnp.int32)[
                decision.astype(jnp.int32)]}


def mab_decide_arrivals(mab_state, shared, ucb_c: float):
    """UCB deployment decisions (eq. 9) for one interval's arrival rows.

    SLAs are batch-normalized exactly as ``MABDecider._norm`` (float64
    math, float32 cast) so the in-kernel context classification matches
    the host decider bit for bit.  Padding rows get a (harmless)
    decision; ``admit`` masks them out.
    """
    sla_n = (shared["sla"] * 40000.0
             / jnp.maximum(shared["batch"].astype(jnp.float64), 1.0)) \
        .astype(jnp.float32)
    d, _ = mab_mod.decide_ucb_batch(mab_state, sla_n, shared["app"], ucb_c)
    return d


def mab_decide_arrivals_train(mab_state, shared, key_t):
    """ε-greedy training decisions (eq. 6) for one interval's arrival
    rows, against the carried ``MABState`` and the interval's fold-in
    key.  SLA normalization matches ``mab_decide_arrivals``; the per-row
    key choreography lives in ``mab.decide_train_rows`` (prefix-stable,
    so the host replay running on the dense valid prefix draws identical
    bits).  Padding rows get a (harmless) decision; ``admit`` masks them
    out.
    """
    sla_n = (shared["sla"] * 40000.0
             / jnp.maximum(shared["batch"].astype(jnp.float64), 1.0)) \
        .astype(jnp.float32)
    d, _ = mab_mod.decide_train_rows(mab_state, key_t, sla_n, shared["app"])
    return d


def mab_feedback(mab_state, state, fin, phi: float, gamma: float, k: float):
    """End-of-interval MAB bookkeeping over the slots that finished.

    Gathers the feedback channels in admission (``seq``) order — the
    canonical order the parity replay feeds the same shared masked
    functions — and applies ``end_of_interval_masked``.
    """
    ordr = jnp.argsort(jnp.where(fin, state["seq"], _SEQ_DEAD))
    batch = state["batch"]               # >= 1 by construction
    sla_n = (state["sla"] * 40000.0 / batch).astype(jnp.float32)
    resp_n = (state["resp"] * 40000.0 / batch).astype(jnp.float32)
    dec = jnp.clip(state["decision"], 0, 1)
    return mab_mod.end_of_interval_masked(
        mab_state, state["app"][ordr], sla_n[ordr], resp_n[ordr],
        state["acc"].astype(jnp.float32)[ordr], dec[ordr], fin[ordr],
        phi, gamma, k)


def gillis_decide_arrivals(Q, eps, shared, key_t, layer_ref):
    """Gillis ε-greedy arm decisions (layer vs compressed) for one
    interval's arrival rows, against the carried Q-table/ε and the
    interval's fold-in key.  Context buckets come straight from the raw
    SLA/batch via the shared ``mab.gillis_bucket`` — no normalization,
    matching the host ``GillisDecider._ctx``.  Padding rows get a
    (harmless) decision; ``admit`` masks them out.
    """
    arms, _ = mab_mod.gillis_decide_rows(
        Q, eps, key_t, shared["sla"],
        shared["batch"].astype(jnp.float64), shared["app"], layer_ref)
    return arms


def gillis_feedback(Q, state, fin, layer_ref, lr: float):
    """End-of-interval Gillis Q-updates over the slots that finished.

    Gathers the feedback channels in admission (``seq``) order — the
    order the host replay walks its finished list — recomputes each
    slot's context bucket from its stored SLA/batch/app, and applies the
    shared sequential ``mab.gillis_update_masked``.
    """
    ordr = jnp.argsort(jnp.where(fin, state["seq"], _SEQ_DEAD))
    bucket = mab_mod.gillis_bucket(state["sla"], state["batch"],
                                   state["app"], layer_ref)
    arm = (state["decision"] != 0).astype(jnp.int32)   # LAYER → arm 0
    reward = ((state["resp"] <= state["sla"]).astype(jnp.float64)
              + state["acc"]) / 2.0
    return mab_mod.gillis_update_masked(
        Q, state["app"][ordr], bucket[ordr], arm[ordr], reward[ordr],
        fin[ordr], lr)


def state_features_k(state, cl, lat_mult, interval_s: float):
    """(n, 4) worker utilization features — the array mirror of
    ``repro.env.soa.state_features`` (cpu load, ram load, net quality,
    placed count), computed post-admit so new fragments (worker −1) are
    excluded exactly as on the host.  float64 censuses; the float32 cast
    happens inside the surrogate input packing.
    """
    n = cl["mips"].shape[0]
    worker, done = state["worker"], state["done"]
    K, F = worker.shape
    wsafe = jnp.clip(worker, 0, n - 1)
    live = (~done) & (worker >= 0)
    oh = _onehot(wsafe, n)
    mips_f = jnp.maximum(cl["mips"][wsafe], 1)
    cpu_v = jnp.where(live, state["instr"] / mips_f / interval_s, 0.0)
    is_stage = jnp.arange(F, dtype=jnp.int32)[None, :] \
        == state["stage"][:, None]
    holds = live & ((~state["chain"][:, None]) | is_stage)
    ram_v = jnp.where(holds, state["ram"] / cl["ram"][wsafe], 0.0)
    stacked = jnp.stack([cpu_v, ram_v, live.astype(jnp.float64)])
    sums = jnp.einsum("ckf,kfn->cn", stacked, oh)
    cpu, ram_load, cnt = sums[0], sums[1], sums[2]
    return jnp.stack([jnp.clip(cpu, 0, 4) / 4.0,
                      jnp.clip(ram_load, 0, 2) / 2.0,
                      1.0 / lat_mult,
                      jnp.clip(cnt, 0, 8) / 8.0], axis=-1)


def _daso_rows(cfg, state, req):
    """Container-row packing shared by the DASO deploy/train stages: the
    first ``cfg.max_containers`` live fragments in admission order (the
    same container enumeration as ``EdgeSim.containers``), each with its
    warm-start worker (current worker or BestFit target from ``req``)
    and clipped split decision."""
    K, F = state["worker"].shape
    n, C = cfg.num_workers, cfg.max_containers
    order = _admission_order(state)
    live = ~state["done"]
    flat_ord = live[order].ravel()
    ncum = jnp.cumsum(flat_ord.astype(jnp.int32))
    n_live = ncum[-1]
    pos = jnp.minimum(jnp.searchsorted(
        ncum, jnp.arange(1, C + 1, dtype=jnp.int32), side="left"),
        K * F - 1)
    slot_i = order[pos // F]
    f_i = (pos % F).astype(jnp.int32)
    rowvalid = jnp.arange(C) < n_live
    warm = jnp.clip(req[slot_i, f_i], 0, n - 1)
    dec_i = jnp.where(rowvalid, jnp.clip(state["decision"][slot_i], 0, 1), 0)
    return slot_i, f_i, rowvalid, warm, dec_i


def daso_requests(cfg, theta, state, feat, req):
    """Array-form DASO placement stage (§5.3 / eqs. 10–12).

    Packs the first ``cfg.max_containers`` live fragments (admission
    order — the same container enumeration as ``EdgeSim.containers``)
    into placement-logit rows warm-started from ``req`` (current worker
    or BestFit target), gradient-ascends the surrogate with
    ``optimize_placement``, and writes each row's argmax worker back into
    the request matrix.  Fragments beyond the container budget keep their
    BestFit request, and ``apply_requests`` feasibility-repairs the
    result — the fallback for infeasible surrogate outputs.
    """
    K, _ = state["worker"].shape
    slot_i, f_i, rowvalid, warm, dec_i = _daso_rows(cfg, state, req)
    logits = daso_mod.warm_start_logits(cfg, warm, rowvalid)
    mask = rowvalid.astype(feat.dtype)
    p_opt, _, _ = daso_mod.optimize_placement(cfg, theta, feat, logits,
                                              dec_i, mask)
    assign = jnp.argmax(p_opt, axis=-1).astype(jnp.int32)
    tgt = jnp.where(rowvalid, slot_i, K)     # K == out of bounds -> drop
    return req.at[tgt, f_i].set(assign, mode="drop")


def daso_requests_train(cfg, theta, state, feat, req, use_opt):
    """Train-mode DASO stage: same row packing/ascent as
    ``daso_requests``, but (a) cold-start gated — until ``use_opt`` the
    warm (BestFit/current-worker) logits are used verbatim, matching the
    host placer before ``place_min`` replay records exist — and (b) it
    also returns this interval's packed surrogate input
    (``daso.pack_input`` of the logits actually used), the features half
    of the (x, O^P) pair the training carry appends to the replay
    window after the physics run.

    ``use_opt`` must be an UNBATCHED scalar (the driver derives it from
    the fori_loop interval index, which the one-record-per-interval
    append invariant makes equivalent to the replay-count gate): the
    ``lax.cond`` then genuinely skips the ascent while-loop — the
    dominant per-interval cost — during cold start, instead of
    computing and discarding it, and stays a real conditional under
    ``vmap``."""
    K, _ = state["worker"].shape
    slot_i, f_i, rowvalid, warm, dec_i = _daso_rows(cfg, state, req)
    logits = daso_mod.warm_start_logits(cfg, warm, rowvalid)
    mask = rowvalid.astype(feat.dtype)
    p_used = lax.cond(
        use_opt,
        lambda _: daso_mod.optimize_placement(cfg, theta, feat, logits,
                                              dec_i, mask)[0],
        lambda _: logits, None)
    assign = jnp.argmax(p_used, axis=-1).astype(jnp.int32)
    tgt = jnp.where(rowvalid, slot_i, K)     # K == out of bounds -> drop
    x = daso_mod.pack_input(cfg, feat, p_used, dec_i, mask)
    return req.at[tgt, f_i].set(assign, mode="drop"), x
