"""Jitted trace/grid drivers: one compiled call per (seed × λ) grid.

``run_trace_arrays`` runs one compiled trace; ``run_grid_arrays`` vmaps
the same interval program over a stacked grid so the sequential greedy
placement loops (the only non-parallel part of the physics) are shared
across every grid cell per iteration.  Executables are cached on the
static configuration (T, A, K, F, n, substeps, interval_s, swap), so a
whole λ-sweep with common shapes compiles exactly once.

Everything runs under ``jax.experimental.enable_x64`` so the float64
elementwise physics matches ``env/soa.py``; the global x64 flag is left
untouched for the rest of the process (models/optimizers stay float32).
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.env.cluster import Cluster, make_cluster
from repro.env.jaxsim import kernels
from repro.env.jaxsim.arrays import (ClusterArrays, TraceArrays,
                                     default_capacity, stack_traces)

_RUNNER_CACHE = {}


#: layout of the packed per-substep metric accumulator (one dot per
#: substep): [n_fin, Σresp, n_viol, Σacc, Σreward, Σwait, fin_dec·3]
METRIC_COLS = ("n_fin", "sum_resp", "n_viol", "sum_acc", "sum_reward",
               "sum_wait", "fin_layer", "fin_semantic", "fin_compressed")


def _init_acc(n: int):
    f8 = jnp.float64
    return {
        "now": jnp.zeros((), f8),
        "energy": jnp.zeros((), f8),
        "pwt": jnp.zeros((n,), f8),
        "metrics": jnp.zeros((len(METRIC_COLS),), f8),
    }


def _trace_program(T, A, K, F, n, substeps, interval_s, swap_slowdown):
    dt = interval_s / substeps

    def run_one(trace, cl):
        state = kernels.init_state(K, F, n)
        acc = _init_acc(n)

        def interval(t, carry):
            state, acc = carry
            arr = {k: trace[k][t] for k in
                   ("valid", "sla", "arrival_s", "acc", "decision",
                    "chain", "nfrag", "instr", "ram", "out_bytes")}
            state = kernels.admit(state, arr)
            state = kernels.place(state, cl)
            state["wait_s"] = state["wait_s"] + jnp.where(
                state["alive"] & ~state["placed"], interval_s, 0.0)
            state, acc, busy = kernels.run_substeps(
                state, acc, trace["bw_mult"][t], cl, substeps=substeps,
                dt=dt, swap_slowdown=swap_slowdown)
            util = busy / interval_s
            power = cl["power_idle"] + (cl["power_peak"] - cl["power_idle"]) \
                * jnp.clip(util, 0.0, 1.0)
            acc = dict(acc)
            acc["energy"] = acc["energy"] + jnp.sum(power) * interval_s
            state = dict(state)
            state["alive"] = state["alive"] & ~state["task_done"]
            return state, acc

        state, acc = lax.fori_loop(0, T, interval, (state, acc))
        return {"metrics": acc["metrics"], "energy": acc["energy"],
                "pwt": acc["pwt"], "dropped": state["dropped"]}

    return run_one


def _get_runner(key, batched: bool):
    ck = key + (batched,)
    if ck not in _RUNNER_CACHE:
        prog = _trace_program(*key)
        if batched:
            prog = jax.vmap(prog, in_axes=(0, None))
        _RUNNER_CACHE[ck] = jax.jit(prog)
    return _RUNNER_CACHE[ck]


def _summarize(out, interval_s: float, n_intervals: int,
               cost_hr_total: float) -> dict:
    """Assemble the §6.4 summary dict (``MetricsAccumulator.summary``
    schema) from kernel accumulators."""
    m = dict(zip(METRIC_COLS, np.asarray(out["metrics"], np.float64)))
    n_fin = m["n_fin"]
    d = max(n_fin, 1.0)
    mean_resp = m["sum_resp"] / d
    mean_wait = m["sum_wait"] / d
    pwt = np.asarray(out["pwt"], np.float64)
    tot = pwt.sum()
    fair = float(tot ** 2 / (len(pwt) * np.sum(pwt ** 2) + 1e-12)) \
        if tot > 0 else 1.0
    cost = cost_hr_total * interval_s / 3600.0 * n_intervals
    return {
        "accuracy": float(m["sum_acc"] / d),
        "sla_violations": float(m["n_viol"] / d),
        "reward": float(m["sum_reward"] / d),
        "response_intervals": float(mean_resp / interval_s),
        "wait_intervals": float(mean_wait / interval_s),
        "exec_intervals": float((mean_resp - mean_wait) / interval_s),
        "energy_mwhr": float(out["energy"]) / 3.6e9,
        "fairness": fair,
        "cost_per_container": float(cost / max(1, int(tot))),
        "layer_fraction": float(m["fin_layer"] / d),
        "tasks_completed": int(n_fin),
        "dropped_tasks": int(out["dropped"]),
    }


def _static_key(trace_leaves, K, n, substeps, interval_s, swap_slowdown):
    shp = trace_leaves["instr"].shape
    T, A, F = shp[-3], shp[-2], shp[-1]
    return (T, A, K, F, n, substeps, interval_s, swap_slowdown)


def run_grid_arrays(traces: Sequence[TraceArrays],
                    cluster: Optional[Cluster] = None,
                    max_active: Optional[int] = None,
                    swap_slowdown: float = 0.5,
                    threads: Optional[int] = None) -> list:
    """Run a whole grid of compiled traces through the jitted vmapped
    program; returns one summary dict per trace (same order).

    The grid is split into ``threads`` equal vmap chunks dispatched from
    a thread pool: jitted XLA executions release the GIL, so chunks run
    on separate cores — parallelism the GIL-bound host interval loop
    cannot have.  Results are independent per trace, so chunking changes
    nothing numerically.  ``threads`` defaults to the core count (capped
    by the grid size); pass 1 to force a single call.
    """
    cluster = cluster or make_cluster()
    cl = ClusterArrays.from_cluster(cluster)
    K = max_active or default_capacity(traces)
    t0 = traces[0]
    for t in traces:
        # checked here, not just inside per-chunk stack_traces: chunking
        # could otherwise split mismatched traces into separate chunks
        # and silently run them under traces[0]'s compiled physics
        if (t.n_intervals, t.interval_s, t.substeps) != \
                (t0.n_intervals, t0.interval_s, t0.substeps):
            raise ValueError("grid cells must share n_intervals/interval_s/"
                             "substeps (shapes are compile-time static)")
    if threads is None:
        threads = max(1, min(os.cpu_count() or 1, len(traces) // 2))
    threads = max(1, min(threads, len(traces)))
    per = -(-len(traces) // threads)
    chunks = [list(traces[i:i + per]) for i in range(0, len(traces), per)]
    with enable_x64():
        cld = {k: jnp.asarray(v) for k, v in cl.as_dict().items()}

        A = max(t.max_arrivals for t in traces)
        F = max(t.max_frags for t in traces)

        def prep(chunk):
            leaves = {k: jnp.asarray(v)
                      for k, v in stack_traces(chunk, max_arrivals=A,
                                               max_frags=F).items()}
            key = _static_key(leaves, K, cl.n, t0.substeps, t0.interval_s,
                              swap_slowdown)
            return _get_runner(key, batched=True), leaves

        # compile (cached) before parallel dispatch so threads only race
        # on execution, never on tracing
        prepped = [prep(c) for c in chunks]

        def run_chunk(rl):
            with enable_x64():       # config contexts are thread-local
                return rl[0](rl[1], cld)

        if len(prepped) == 1:
            outs = [run_chunk(prepped[0])]
        else:
            with ThreadPoolExecutor(max_workers=len(prepped)) as ex:
                outs = list(ex.map(run_chunk, prepped))
        outs = [jax.tree_util.tree_map(np.asarray, o) for o in outs]
    cost_total = float(cl.cost_hr.sum())
    results = []
    for chunk, out in zip(chunks, outs):
        for i, _ in enumerate(chunk):
            results.append(_summarize(
                {k: (v[i] if np.ndim(v) > 0 else v) for k, v in out.items()},
                t0.interval_s, t0.n_intervals, cost_total))
    return results


def run_trace_arrays(trace: TraceArrays, cluster: Optional[Cluster] = None,
                     max_active: Optional[int] = None,
                     swap_slowdown: float = 0.5) -> dict:
    """Run one compiled trace through the (unbatched) jitted program."""
    cluster = cluster or make_cluster()
    cl = ClusterArrays.from_cluster(cluster)
    K = max_active or default_capacity([trace])
    with enable_x64():
        leaves = {k: jnp.asarray(v) for k, v in trace.kernel_dict().items()}
        cld = {k: jnp.asarray(v) for k, v in cl.as_dict().items()}
        key = _static_key(leaves, K, cl.n, trace.substeps, trace.interval_s,
                          swap_slowdown)
        runner = _get_runner(key, batched=False)
        out = jax.tree_util.tree_map(np.asarray, runner(leaves, cld))
    return _summarize(out, trace.interval_s, trace.n_intervals,
                      float(cl.cost_hr.sum()))
