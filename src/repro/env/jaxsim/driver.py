"""Jitted trace/grid drivers: one compiled call per (seed × λ) grid.

``run_trace_arrays`` runs one compiled trace; ``run_grid_arrays`` vmaps
the same interval program over a stacked grid so the sequential greedy
placement loops (the only non-parallel part of the physics) are shared
across every grid cell per iteration.  Executables are cached on the
static configuration (T, A, K, F, n, substeps, interval_s, swap), so a
whole λ-sweep with common shapes compiles exactly once.

Everything runs under ``jax.experimental.enable_x64`` so the float64
elementwise physics matches ``env/soa.py``; the global x64 flag is left
untouched for the rest of the process (models/optimizers stay float32).
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.env.cluster import Cluster, make_cluster
from repro.env.jaxsim import kernels
from repro.env.jaxsim.arrays import (ClusterArrays, DualTraceArrays,
                                     TraceArrays, default_capacity,
                                     stack_traces)

_RUNNER_CACHE = {}

#: MAB hyperparameters of the in-kernel learned policies, matching the
#: host ``MABDecider`` defaults: (ucb_c, phi, gamma, k)
MAB_HP = (0.5, 0.3, 0.3, 0.1)


#: layout of the packed per-substep metric accumulator (one dot per
#: substep): [n_fin, Σresp, n_viol, Σacc, Σreward, Σwait, fin_dec·3]
METRIC_COLS = ("n_fin", "sum_resp", "n_viol", "sum_acc", "sum_reward",
               "sum_wait", "fin_layer", "fin_semantic", "fin_compressed")


def _init_acc(n: int):
    f8 = jnp.float64
    return {
        "now": jnp.zeros((), f8),
        "energy": jnp.zeros((), f8),
        "pwt": jnp.zeros((n,), f8),
        "metrics": jnp.zeros((len(METRIC_COLS),), f8),
    }


def _interval_physics(state, acc, bw_row, cl, substeps, dt, interval_s,
                      swap_slowdown):
    """Shared interval tail for every trace program: waiting-time
    accounting, the substep physics, and the utilization → power →
    energy accumulation.  Static and learned programs differ only in
    their decide/place/feedback hooks around this.  Also returns the
    per-worker interval utilization (the AEC ingredient of the DASO
    training target, eq. 10)."""
    state = dict(state)
    state["wait_s"] = state["wait_s"] + jnp.where(
        state["alive"] & ~state["placed"], interval_s, 0.0)
    state, acc, busy = kernels.run_substeps(
        state, acc, bw_row, cl, substeps=substeps, dt=dt,
        swap_slowdown=swap_slowdown)
    util = busy / interval_s
    power = cl["power_idle"] + (cl["power_peak"] - cl["power_idle"]) \
        * jnp.clip(util, 0.0, 1.0)
    acc = dict(acc)
    acc["energy"] = acc["energy"] + jnp.sum(power) * interval_s
    return state, acc, util


def _trace_program(T, A, K, F, n, substeps, interval_s, swap_slowdown):
    dt = interval_s / substeps

    def run_one(trace, cl):
        state = kernels.init_state(K, F, n)
        acc = _init_acc(n)

        def interval(t, carry):
            state, acc = carry
            arr = {k: trace[k][t] for k in
                   ("valid", "sla", "arrival_s", "app", "batch", "acc",
                    "decision", "chain", "nfrag", "instr", "ram",
                    "out_bytes")}
            state = kernels.admit(state, arr)
            state = kernels.place(state, cl)
            state, acc, _ = _interval_physics(
                state, acc, trace["bw_mult"][t], cl, substeps, dt,
                interval_s, swap_slowdown)
            state["alive"] = state["alive"] & ~state["task_done"]
            return state, acc

        state, acc = lax.fori_loop(0, T, interval, (state, acc))
        return {"metrics": acc["metrics"], "energy": acc["energy"],
                "pwt": acc["pwt"], "dropped": state["dropped"]}

    return run_one


def _get_runner(key, batched: bool):
    ck = key + (batched,)
    if ck not in _RUNNER_CACHE:
        prog = _trace_program(*key)
        if batched:
            prog = jax.vmap(prog, in_axes=(0, None))
        _RUNNER_CACHE[ck] = jax.jit(prog)
    return _RUNNER_CACHE[ck]


def _summarize(out, interval_s: float, n_intervals: int,
               cost_hr_total: float) -> dict:
    """Assemble the §6.4 summary dict (``MetricsAccumulator.summary``
    schema) from kernel accumulators."""
    m = dict(zip(METRIC_COLS, np.asarray(out["metrics"], np.float64)))
    n_fin = m["n_fin"]
    d = max(n_fin, 1.0)
    mean_resp = m["sum_resp"] / d
    mean_wait = m["sum_wait"] / d
    pwt = np.asarray(out["pwt"], np.float64)
    tot = pwt.sum()
    fair = float(tot ** 2 / (len(pwt) * np.sum(pwt ** 2) + 1e-12)) \
        if tot > 0 else 1.0
    cost = cost_hr_total * interval_s / 3600.0 * n_intervals
    return {
        "accuracy": float(m["sum_acc"] / d),
        "sla_violations": float(m["n_viol"] / d),
        "reward": float(m["sum_reward"] / d),
        "response_intervals": float(mean_resp / interval_s),
        "wait_intervals": float(mean_wait / interval_s),
        "exec_intervals": float((mean_resp - mean_wait) / interval_s),
        "energy_mwhr": float(out["energy"]) / 3.6e9,
        "fairness": fair,
        "cost_per_container": float(cost / max(1, int(tot))),
        "layer_fraction": float(m["fin_layer"] / d),
        "tasks_completed": int(n_fin),
        "dropped_tasks": int(out["dropped"]),
    }


def _static_key(trace_leaves, K, n, substeps, interval_s, swap_slowdown):
    shp = trace_leaves["instr"].shape
    T, A, F = shp[-3], shp[-2], shp[-1]
    return (T, A, K, F, n, substeps, interval_s, swap_slowdown)


def _run_chunks(prepped, extra_args):
    """Execute (runner, stacked-leaves) chunks, one thread per chunk:
    jitted XLA executions release the GIL, so chunks run on separate
    cores — parallelism the GIL-bound host interval loop cannot have.
    Results are independent per trace, so chunking changes nothing
    numerically."""
    def run_chunk(rl):
        with enable_x64():       # config contexts are thread-local
            return rl[0](rl[1], *extra_args)

    if len(prepped) == 1:
        outs = [run_chunk(prepped[0])]
    else:
        with ThreadPoolExecutor(max_workers=len(prepped)) as ex:
            outs = list(ex.map(run_chunk, prepped))
    return [jax.tree_util.tree_map(np.asarray, o) for o in outs]


def _grid_chunks(traces, threads):
    """Validate grid homogeneity and split it into thread chunks."""
    t0 = traces[0]
    for t in traces:
        # checked here, not just inside per-chunk stack_traces: chunking
        # could otherwise split mismatched traces into separate chunks
        # and silently run them under traces[0]'s compiled physics
        if (t.n_intervals, t.interval_s, t.substeps) != \
                (t0.n_intervals, t0.interval_s, t0.substeps):
            raise ValueError("grid cells must share n_intervals/interval_s/"
                             "substeps (shapes are compile-time static)")
    if threads is None:
        threads = max(1, min(os.cpu_count() or 1, len(traces) // 2))
    threads = max(1, min(threads, len(traces)))
    per = -(-len(traces) // threads)
    return [list(traces[i:i + per]) for i in range(0, len(traces), per)]


def run_grid_arrays(traces: Sequence[TraceArrays],
                    cluster: Optional[Cluster] = None,
                    max_active: Optional[int] = None,
                    swap_slowdown: float = 0.5,
                    threads: Optional[int] = None) -> list:
    """Run a whole grid of compiled traces through the jitted vmapped
    program; returns one summary dict per trace (same order).

    The grid is split into ``threads`` equal vmap chunks dispatched from
    a thread pool: jitted XLA executions release the GIL, so chunks run
    on separate cores — parallelism the GIL-bound host interval loop
    cannot have.  Results are independent per trace, so chunking changes
    nothing numerically.  ``threads`` defaults to the core count (capped
    by the grid size); pass 1 to force a single call.
    """
    cluster = cluster or make_cluster()
    cl = ClusterArrays.from_cluster(cluster)
    K = max_active or default_capacity(traces)
    t0 = traces[0]
    chunks = _grid_chunks(traces, threads)
    with enable_x64():
        cld = {k: jnp.asarray(v) for k, v in cl.as_dict().items()}

        A = max(t.max_arrivals for t in traces)
        F = max(t.max_frags for t in traces)

        def prep(chunk):
            leaves = {k: jnp.asarray(v)
                      for k, v in stack_traces(chunk, max_arrivals=A,
                                               max_frags=F).items()}
            key = _static_key(leaves, K, cl.n, t0.substeps, t0.interval_s,
                              swap_slowdown)
            return _get_runner(key, batched=True), leaves

        # compile (cached) before parallel dispatch so threads only race
        # on execution, never on tracing
        prepped = [prep(c) for c in chunks]
        outs = _run_chunks(prepped, (cld,))
    cost_total = float(cl.cost_hr.sum())
    results = []
    for chunk, out in zip(chunks, outs):
        for i, _ in enumerate(chunk):
            results.append(_summarize(
                {k: (v[i] if np.ndim(v) > 0 else v) for k, v in out.items()},
                t0.interval_s, t0.n_intervals, cost_total))
    return results


def run_trace_arrays(trace: TraceArrays, cluster: Optional[Cluster] = None,
                     max_active: Optional[int] = None,
                     swap_slowdown: float = 0.5) -> dict:
    """Run one compiled trace through the (unbatched) jitted program."""
    cluster = cluster or make_cluster()
    cl = ClusterArrays.from_cluster(cluster)
    K = max_active or default_capacity([trace])
    with enable_x64():
        leaves = {k: jnp.asarray(v) for k, v in trace.kernel_dict().items()}
        cld = {k: jnp.asarray(v) for k, v in cl.as_dict().items()}
        key = _static_key(leaves, K, cl.n, trace.substeps, trace.interval_s,
                          swap_slowdown)
        runner = _get_runner(key, batched=False)
        out = jax.tree_util.tree_map(np.asarray, runner(leaves, cld))
    return _summarize(out, trace.interval_s, trace.n_intervals,
                      float(cl.cost_hr.sum()))


# -------------------------------------------------- learned-policy driver
#
# The SplitPlace learning loop runs *inside* the jitted interval program:
# the carried ``MABState`` takes UCB split decisions over each interval's
# arrival rows, the optional array-form DASO stage gradient-ascends the
# placement surrogate between the BestFit request and repair stages, and
# the Algorithm-1 feedback (reward buckets, RBED ε-decay, R-estimate EMA)
# closes the loop before the next interval — thousands of host round
# trips become one compiled call per grid.

_LEARNED_CACHE = {}

#: extra summary keys the learned runners report on top of the §6.4
#: schema: the final carried MAB state's scalars (trajectory fingerprint
#: for the parity contract)
LEARNED_EXTRA_COLS = ("mab_eps", "mab_rho", "mab_t")


def _learned_trace_program(T, A, K, F, n, substeps, interval_s,
                           swap_slowdown, daso_cfg, mab_hp):
    dt = interval_s / substeps
    ucb_c, phi, gamma, k_rbed = mab_hp
    shared_keys = ("valid", "sla", "arrival_s", "app", "batch")
    var_keys = ("vacc", "vchain", "vnfrag", "vinstr", "vram", "vout")

    def run_one(trace, cl, mab0, theta):
        state = kernels.init_state(K, F, n)
        acc = _init_acc(n)

        def interval(t, carry):
            state, acc, mab = carry
            shared = {key: trace[key][t] for key in shared_keys}
            var = {key: trace[key][t] for key in var_keys}
            d = kernels.mab_decide_arrivals(mab, shared, ucb_c)
            state = kernels.admit(state, kernels.select_variant(
                shared, var, d))
            req = kernels.bestfit_requests(state, cl)
            if daso_cfg is not None:
                feat = kernels.state_features_k(
                    state, cl, trace["lat_prev"][t], interval_s)
                req = kernels.daso_requests(daso_cfg, theta, state, feat,
                                            req)
            state = kernels.apply_requests(state, cl, req)
            prev_done = state["task_done"]
            state, acc, _ = _interval_physics(
                state, acc, trace["bw_mult"][t], cl, substeps, dt,
                interval_s, swap_slowdown)
            mab = kernels.mab_feedback(
                mab, state, state["task_done"] & ~prev_done,
                phi, gamma, k_rbed)
            state["alive"] = state["alive"] & ~state["task_done"]
            return state, acc, mab

        state, acc, mab = lax.fori_loop(0, T, interval, (state, acc, mab0))
        return {"metrics": acc["metrics"], "energy": acc["energy"],
                "pwt": acc["pwt"], "dropped": state["dropped"],
                "mab_eps": mab.eps, "mab_rho": mab.rho, "mab_t": mab.t}

    return run_one


def _get_learned_runner(key, batched: bool):
    ck = key + (batched,)
    if ck not in _LEARNED_CACHE:
        prog = _learned_trace_program(*key)
        if batched:
            prog = jax.vmap(prog, in_axes=(0, None, None, None))
        _LEARNED_CACHE[ck] = jax.jit(prog)
    return _LEARNED_CACHE[ck]


def _learned_static_key(trace_leaves, K, n, substeps, interval_s,
                        swap_slowdown, daso_cfg, mab_hp):
    shp = trace_leaves["vinstr"].shape
    T, A, F = shp[-4], shp[-3], shp[-1]
    return (T, A, K, F, n, substeps, interval_s, swap_slowdown, daso_cfg,
            mab_hp)


def _check_learned_args(daso_cfg, daso_theta, n):
    if daso_cfg is None:
        return ()                         # BestFit placement: no surrogate
    if daso_theta is None:
        raise ValueError("the DASO placer needs pretrained theta "
                         "(see launch.experiments.pretrain)")
    if daso_cfg.num_workers != n:
        raise ValueError(f"daso_cfg.num_workers={daso_cfg.num_workers} "
                         f"!= cluster size {n}")
    return daso_theta


def _learned_summary(out, t0, cost_total):
    s = _summarize(out, t0.interval_s, t0.n_intervals, cost_total)
    s["mab_eps"] = float(out["mab_eps"])
    s["mab_rho"] = float(out["mab_rho"])
    s["mab_t"] = int(out["mab_t"])
    return s


def run_grid_arrays_learned(traces: Sequence[DualTraceArrays], mab_state,
                            daso_theta=None, daso_cfg=None,
                            cluster: Optional[Cluster] = None,
                            max_active: Optional[int] = None,
                            swap_slowdown: float = 0.5,
                            threads: Optional[int] = None,
                            mab_hp=MAB_HP) -> list:
    """Run a grid of dual traces under the in-kernel learned policy —
    online UCB MAB split decisions, plus the array-form DASO placer when
    ``daso_cfg``/``daso_theta`` are given (BestFit otherwise).

    Every grid cell carries its own copy of ``mab_state`` through the
    interval loop (the pretrained state is the shared starting point, the
    online feedback trajectories diverge per cell).  Returns one summary
    dict per trace extended with the final MAB scalars
    (``LEARNED_EXTRA_COLS``)."""
    cluster = cluster or make_cluster()
    cl = ClusterArrays.from_cluster(cluster)
    K = max_active or default_capacity(traces)
    theta = _check_learned_args(daso_cfg, daso_theta, cl.n)
    t0 = traces[0]
    chunks = _grid_chunks(traces, threads)
    with enable_x64():
        cld = {k: jnp.asarray(v) for k, v in cl.as_dict().items()}
        mab0 = jax.tree_util.tree_map(jnp.asarray, mab_state)
        theta = jax.tree_util.tree_map(jnp.asarray, theta)
        A = max(t.max_arrivals for t in traces)
        F = max(t.max_frags for t in traces)

        def prep(chunk):
            leaves = {k: jnp.asarray(v)
                      for k, v in stack_traces(chunk, max_arrivals=A,
                                               max_frags=F).items()}
            key = _learned_static_key(leaves, K, cl.n, t0.substeps,
                                      t0.interval_s, swap_slowdown,
                                      daso_cfg, tuple(mab_hp))
            return _get_learned_runner(key, batched=True), leaves

        prepped = [prep(c) for c in chunks]
        outs = _run_chunks(prepped, (cld, mab0, theta))
    cost_total = float(cl.cost_hr.sum())
    results = []
    for chunk, out in zip(chunks, outs):
        for i, _ in enumerate(chunk):
            results.append(_learned_summary(
                {k: (v[i] if np.ndim(v) > 0 else v) for k, v in out.items()},
                t0, cost_total))
    return results


def run_trace_arrays_learned(trace: DualTraceArrays, mab_state,
                             daso_theta=None, daso_cfg=None,
                             cluster: Optional[Cluster] = None,
                             max_active: Optional[int] = None,
                             swap_slowdown: float = 0.5,
                             mab_hp=MAB_HP) -> dict:
    """Run one dual trace through the (unbatched) learned-policy program."""
    cluster = cluster or make_cluster()
    cl = ClusterArrays.from_cluster(cluster)
    K = max_active or default_capacity([trace])
    theta = _check_learned_args(daso_cfg, daso_theta, cl.n)
    with enable_x64():
        leaves = {k: jnp.asarray(v) for k, v in trace.kernel_dict().items()}
        cld = {k: jnp.asarray(v) for k, v in cl.as_dict().items()}
        mab0 = jax.tree_util.tree_map(jnp.asarray, mab_state)
        theta = jax.tree_util.tree_map(jnp.asarray, theta)
        key = _learned_static_key(leaves, K, cl.n, trace.substeps,
                                  trace.interval_s, swap_slowdown,
                                  daso_cfg, tuple(mab_hp))
        runner = _get_learned_runner(key, batched=False)
        out = jax.tree_util.tree_map(np.asarray,
                                     runner(leaves, cld, mab0, theta))
    return _learned_summary(out, trace, float(cl.cost_hr.sum()))


# -------------------------------------------------- in-kernel training
#
# mode="train" moves the full §6.3 training loop inside the jitted
# interval program: ε-greedy MAB decisions (eq. 6, RBED ε-decay per
# Algorithm 1) drawn from a fold-in key threaded through the carry, and
# decision-aware DASO finetuning (eqs. 10-12) — each interval's (packed
# placement features, O^P) pair is appended to the carried fixed
# 64-row replay window and ``daso.train_epoch_weighted`` advances
# (theta, opt_state) in-kernel, so the surrogate the placer ascends is
# the finetuned one, not the frozen pretrain snapshot.  The parity
# oracle is ``reference.replay_trace_edgesim_trained``, built from the
# identical shared pure functions.

_TRAINED_CACHE = {}

#: DASO finetuning hyperparameters, matching the host ``SurrogatePlacer``
#: defaults: (alpha, beta, train_steps, place_min, train_min) — the last
#: two are the cold-start gates (ascend the surrogate only after
#: ``place_min`` replay records, train only after ``train_min``);
#: lowering them lets short test/benchmark horizons exercise the
#: finetuned-ascent path the defaults reserve for long traces
TRAIN_HP = (0.5, 0.5, 4, 32, 8)


def _trained_trace_program(T, A, K, F, n, substeps, interval_s,
                           swap_slowdown, daso_cfg, mab_hp, train_hp):
    dt = interval_s / substeps
    _, phi, gamma, k_rbed = mab_hp         # ucb_c unused: eq. 6 decisions
    alpha, beta, train_steps, place_min, train_min = train_hp
    shared_keys = ("valid", "sla", "arrival_s", "app", "batch")
    var_keys = ("vacc", "vchain", "vnfrag", "vinstr", "vram", "vout")

    def run_one(trace, cl, mab0, theta0, opt0, trace_key):
        from repro.core import daso as daso_mod
        state = kernels.init_state(K, F, n)
        acc = _init_acc(n)
        win0 = daso_mod.window_init(daso_cfg) if daso_cfg is not None \
            else {}

        def interval(t, carry):
            state, acc, mab, theta, opt, win = carry
            shared = {key: trace[key][t] for key in shared_keys}
            var = {key: trace[key][t] for key in var_keys}
            key_t = jax.random.fold_in(trace_key, t)
            d = kernels.mab_decide_arrivals_train(mab, shared, key_t)
            state = kernels.admit(state, kernels.select_variant(
                shared, var, d))
            req = kernels.bestfit_requests(state, cl)
            if daso_cfg is not None:
                feat = kernels.state_features_k(
                    state, cl, trace["lat_prev"][t], interval_s)
                # cold-start gate reads the PRE-interval record count —
                # place happens before this interval's (x, y) append,
                # and exactly one record lands per interval, so the
                # count equals the (unbatched) interval index: gating on
                # t keeps lax.cond a real branch under vmap and lets it
                # skip the ascent during cold start
                use_opt = t >= place_min
                req, x = kernels.daso_requests_train(
                    daso_cfg, theta, state, feat, req, use_opt)
            state = kernels.apply_requests(state, cl, req)
            prev_done = state["task_done"]
            state, acc, util = _interval_physics(
                state, acc, trace["bw_mult"][t], cl, substeps, dt,
                interval_s, swap_slowdown)
            fin = state["task_done"] & ~prev_done
            mab = kernels.mab_feedback(mab, state, fin, phi, gamma, k_rbed)
            if daso_cfg is not None:
                y = daso_mod.op_objective(
                    state["resp"], state["sla"], state["acc"], fin, util,
                    interval_s, alpha, beta)
                win = daso_mod.window_append(win, x, y)
                theta, opt = daso_mod.finetune_window(
                    daso_cfg, theta, opt, win, train_steps, train_min)
            state["alive"] = state["alive"] & ~state["task_done"]
            return state, acc, mab, theta, opt, win

        state, acc, mab, theta, opt, _ = lax.fori_loop(
            0, T, interval, (state, acc, mab0, theta0, opt0, win0))
        out = {"metrics": acc["metrics"], "energy": acc["energy"],
               "pwt": acc["pwt"], "dropped": state["dropped"],
               "mab_eps": mab.eps, "mab_rho": mab.rho, "mab_t": mab.t}
        if daso_cfg is not None:
            out["daso_theta"] = theta
        return out

    return run_one


def _get_trained_runner(key, batched: bool):
    ck = key + (batched,)
    if ck not in _TRAINED_CACHE:
        prog = _trained_trace_program(*key)
        if batched:
            prog = jax.vmap(prog, in_axes=(0, None, None, None, None, 0))
        _TRAINED_CACHE[ck] = jax.jit(prog)
    return _TRAINED_CACHE[ck]


def _trained_static_key(trace_leaves, K, n, substeps, interval_s,
                        swap_slowdown, daso_cfg, mab_hp, train_hp):
    shp = trace_leaves["vinstr"].shape
    T, A, F = shp[-4], shp[-3], shp[-1]
    return (T, A, K, F, n, substeps, interval_s, swap_slowdown, daso_cfg,
            tuple(mab_hp), tuple(train_hp))


def _trained_opt_state(daso_cfg, theta, daso_opt_state):
    """The AdamW state the training carry starts from — fresh zeros when
    the caller didn't hand over the pretraining optimizer moments."""
    if daso_cfg is None:
        return ()
    from repro.optim.optimizers import adamw_init
    if daso_opt_state is None:
        return adamw_init(theta)
    return daso_opt_state


def trace_train_key(seed: int):
    """The per-trace decision PRNG key of the in-kernel training loop —
    shared with ``reference.replay_trace_edgesim_trained`` so both
    backends draw identical ε-greedy bits."""
    return jax.random.PRNGKey(seed)


def _trained_summary(out, t0, cost_total):
    s = _learned_summary(out, t0, cost_total)
    if "daso_theta" in out:
        s["daso_theta"] = out["daso_theta"]
    return s


def run_grid_arrays_trained(traces: Sequence[DualTraceArrays], mab_state,
                            daso_theta=None, daso_cfg=None,
                            daso_opt_state=None,
                            cluster: Optional[Cluster] = None,
                            max_active: Optional[int] = None,
                            swap_slowdown: float = 0.5,
                            threads: Optional[int] = None,
                            mab_hp=MAB_HP, train_hp=TRAIN_HP) -> list:
    """Run a grid of dual traces with the FULL training loop in-kernel:
    ε-greedy MAB decisions + Algorithm-1 feedback, and (when
    ``daso_cfg``/``daso_theta`` are given) online DASO finetuning —
    replay-window appends and ``train_epoch_weighted`` steps inside the
    jitted interval program.

    Every grid cell carries its own copies of ``mab_state`` and the
    DASO trainer (theta, opt_state, replay window); per-cell decision
    randomness comes from ``trace_train_key(trace.seed)``.  Summaries
    gain the final MAB scalars and (DASO runs) the finetuned ``theta``
    pytree under ``"daso_theta"``."""
    cluster = cluster or make_cluster()
    cl = ClusterArrays.from_cluster(cluster)
    K = max_active or default_capacity(traces)
    theta = _check_learned_args(daso_cfg, daso_theta, cl.n)
    t0 = traces[0]
    chunks = _grid_chunks(traces, threads)
    with enable_x64():
        cld = {k: jnp.asarray(v) for k, v in cl.as_dict().items()}
        mab0 = jax.tree_util.tree_map(jnp.asarray, mab_state)
        theta = jax.tree_util.tree_map(jnp.asarray, theta)
        opt0 = jax.tree_util.tree_map(
            jnp.asarray, _trained_opt_state(daso_cfg, theta, daso_opt_state))
        A = max(t.max_arrivals for t in traces)
        F = max(t.max_frags for t in traces)

        def prep(chunk):
            leaves = {k: jnp.asarray(v)
                      for k, v in stack_traces(chunk, max_arrivals=A,
                                               max_frags=F).items()}
            keys = jnp.stack([trace_train_key(t.seed) for t in chunk])
            skey = _trained_static_key(leaves, K, cl.n, t0.substeps,
                                       t0.interval_s, swap_slowdown,
                                       daso_cfg, mab_hp, train_hp)
            runner = _get_trained_runner(skey, batched=True)
            # bind the per-chunk key batch so _run_chunks' (runner,
            # leaves) calling convention stays unchanged
            return (lambda l, r_=runner, k_=keys:
                    r_(l, cld, mab0, theta, opt0, k_)), leaves

        prepped = [prep(c) for c in chunks]
        outs = _run_chunks(prepped, ())
    cost_total = float(cl.cost_hr.sum())
    results = []
    for chunk, out in zip(chunks, outs):
        for i, _ in enumerate(chunk):
            results.append(_trained_summary(
                jax.tree_util.tree_map(
                    lambda v: v[i] if np.ndim(v) > 0 else v, out),
                t0, cost_total))
    return results


def run_trace_arrays_trained(trace: DualTraceArrays, mab_state,
                             daso_theta=None, daso_cfg=None,
                             daso_opt_state=None,
                             cluster: Optional[Cluster] = None,
                             max_active: Optional[int] = None,
                             swap_slowdown: float = 0.5,
                             mab_hp=MAB_HP, train_hp=TRAIN_HP) -> dict:
    """Run one dual trace through the (unbatched) in-kernel training
    program."""
    cluster = cluster or make_cluster()
    cl = ClusterArrays.from_cluster(cluster)
    K = max_active or default_capacity([trace])
    theta = _check_learned_args(daso_cfg, daso_theta, cl.n)
    with enable_x64():
        leaves = {k: jnp.asarray(v) for k, v in trace.kernel_dict().items()}
        cld = {k: jnp.asarray(v) for k, v in cl.as_dict().items()}
        mab0 = jax.tree_util.tree_map(jnp.asarray, mab_state)
        theta = jax.tree_util.tree_map(jnp.asarray, theta)
        opt0 = jax.tree_util.tree_map(
            jnp.asarray, _trained_opt_state(daso_cfg, theta, daso_opt_state))
        key = _trained_static_key(leaves, K, cl.n, trace.substeps,
                                  trace.interval_s, swap_slowdown,
                                  daso_cfg, mab_hp, train_hp)
        runner = _get_trained_runner(key, batched=False)
        out = jax.tree_util.tree_map(
            np.asarray, runner(leaves, cld, mab0, theta, opt0,
                               trace_train_key(trace.seed)))
    return _trained_summary(out, trace, float(cl.cost_hr.sum()))
