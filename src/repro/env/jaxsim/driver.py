"""Jitted trace/grid drivers: one compiled call per (seed × λ) grid.

ONE interval program for every policy.  ``_trace_program(engine, ...)``
threads the unified carry ``(state, acc, engine_state)`` through a
``lax.fori_loop`` over intervals and calls the engine's
``decide / place / feedback`` hooks around the shared physics
(``repro.env.jaxsim.engines`` documents the protocol and implements the
zoo: static, MAB deploy ± DASO/GOBI, full §6.3 training, Gillis).  One
runner cache, one static key, one chunk dispatcher and one summary path
serve every engine — adding a policy adds an engine + a host parity
oracle, never another driver copy.

``run_trace_arrays*`` / ``run_grid_arrays*`` are thin engine-selecting
wrappers kept for API stability; ``run_trace_engine`` /
``run_grid_engine`` are the generic entry points.

Executables are cached on ``(engine, T, A, K, F, n, substeps,
interval_s, swap)`` — engines are frozen hashable dataclasses — so a
whole λ-sweep with common shapes compiles exactly once per engine.
Everything runs under ``jax.experimental.enable_x64`` so the float64
elementwise physics matches ``env/soa.py``; the global x64 flag is left
untouched for the rest of the process (models/optimizers stay float32).
"""
from __future__ import annotations

import os
import warnings
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.env.cluster import Cluster, make_cluster
from repro.env.jaxsim import engines, kernels
from repro.env.jaxsim.arrays import (ClusterArrays, DualTraceArrays,
                                     TraceArrays, default_capacity,
                                     stack_traces)
from repro.env.metrics import TELEMETRY_COLS, series_percentiles
from repro.obs import get_ledger

#: LRU-bounded executable cache.  A long-lived serving process sweeps
#: many configs over its lifetime; an unbounded dict of compiled
#: executables is a real leak there, so insertion beyond the cap evicts
#: the least-recently-used runner (XLA frees the executable once the
#: last reference drops).
_RUNNER_CACHE: "OrderedDict" = OrderedDict()
_CACHE_LIMIT = [max(1, int(os.environ.get("JAXSIM_RUNNER_CACHE_MAX",
                                          "64")))]
_EVICTED = set()          # evicted keys, to flag eviction-induced recompiles

#: runner-cache observability: misses were silent recompiles before —
#: every ``_get_runner``/``_get_sharded_runner`` consult now counts, and
#: an engine config that compiles under a SECOND distinct static key
#: logs a ledger warning (the classic symptom of an accidentally
#: shape-polymorphic sweep).
_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}
_CACHE_KEYS = {}          # static-key repr -> compile count
_ENGINE_KEYS = {}         # engine repr -> set of distinct compiled keys


def cache_stats() -> dict:
    """Snapshot of the runner-cache counters: hits/misses/evictions,
    resident executable count, the LRU cap, and the per-key static-key
    reprs with their compile counts (feed it to
    ``RunLedger.add_cache_stats``)."""
    return {"hits": _CACHE_STATS["hits"], "misses": _CACHE_STATS["misses"],
            "evictions": _CACHE_STATS["evictions"],
            "size": len(_RUNNER_CACHE), "limit": _CACHE_LIMIT[0],
            "keys": dict(_CACHE_KEYS)}


def set_cache_limit(limit: int) -> int:
    """Set the LRU cap of the runner cache (also settable process-wide
    via ``JAXSIM_RUNNER_CACHE_MAX``); returns the previous cap.  Shrinking
    below the resident count evicts immediately."""
    if limit < 1:
        raise ValueError(f"cache limit must be >= 1, got {limit}")
    old = _CACHE_LIMIT[0]
    _CACHE_LIMIT[0] = int(limit)
    _evict_to_limit()
    return old


def clear_cache() -> None:
    """Drop every cached executable and reset the cache counters — the
    long-lived-process escape hatch (a serving loop that has moved on to
    a new config can release the old executables' memory at once)."""
    _RUNNER_CACHE.clear()
    _EVICTED.clear()
    _CACHE_KEYS.clear()
    _ENGINE_KEYS.clear()
    _CACHE_STATS.update(hits=0, misses=0, evictions=0)


def _evict_to_limit():
    while len(_RUNNER_CACHE) > _CACHE_LIMIT[0]:
        ck, _ = _RUNNER_CACHE.popitem(last=False)
        _EVICTED.add(ck)
        _CACHE_STATS["evictions"] += 1
        get_ledger().count("runner_cache.eviction")


def _cache_put(ck, runner):
    _RUNNER_CACHE[ck] = runner
    _evict_to_limit()


def _cache_get(ck):
    _RUNNER_CACHE.move_to_end(ck)      # LRU touch
    return _RUNNER_CACHE[ck]


def _note_cache(ck, hit: bool):
    led = get_ledger()
    if hit:
        _CACHE_STATS["hits"] += 1
        led.count("runner_cache.hit")
        return
    _CACHE_STATS["misses"] += 1
    led.count("runner_cache.miss")
    kr = repr(ck)
    _CACHE_KEYS[kr] = _CACHE_KEYS.get(kr, 0) + 1
    er = repr(ck[0])
    keys = _ENGINE_KEYS.setdefault(er, set())
    keys.add(kr)
    if ck in _EVICTED:
        _EVICTED.discard(ck)
        led.warn("eviction-induced recompile: this static key was evicted "
                 f"by the LRU cap ({_CACHE_LIMIT[0]}) and is compiling "
                 "again — raise the cap (set_cache_limit / "
                 "JAXSIM_RUNNER_CACHE_MAX) if this config is hot",
                 engine=er, limit=_CACHE_LIMIT[0])
    elif len(keys) > 1:
        led.warn(f"engine config recompiled: {len(keys)} distinct static "
                 f"keys compiled for {er} — check for shape-polymorphic "
                 "sweeps (T/A/K/F/n or dispatch knobs varying per call)",
                 engine=er, n_keys=len(keys))


_DONATION_OK = {}         # backend name -> probed donation support


def _donation_ok() -> bool:
    """Probe (once per backend) whether jit buffer donation actually
    releases the argument buffer.  XLA:CPU gained donation support only
    recently, so instead of hard-coding a backend list the driver donates
    wherever the probe shows the buffer really dies — and keeps the old
    no-donation behavior (plus no spurious warnings) everywhere else."""
    backend = jax.default_backend()
    ok = _DONATION_OK.get(backend)
    if ok is None:
        probe = jnp.zeros((8,), jnp.float32)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            jax.block_until_ready(
                jax.jit(lambda v: v + 1.0, donate_argnums=0)(probe))
        ok = _DONATION_OK[backend] = bool(probe.is_deleted())
    return ok

#: MAB hyperparameters of the in-kernel learned policies, matching the
#: host ``MABDecider`` defaults: (ucb_c, phi, gamma, k)
MAB_HP = (0.5, 0.3, 0.3, 0.1)

#: DASO finetuning hyperparameters, matching the host ``SurrogatePlacer``
#: defaults: (alpha, beta, train_steps, place_min, train_min) — the last
#: two are the cold-start gates (ascend the surrogate only after
#: ``place_min`` replay records, train only after ``train_min``);
#: lowering them lets short test/benchmark horizons exercise the
#: finetuned-ascent path the defaults reserve for long traces
TRAIN_HP = (0.5, 0.5, 4, 32, 8)

#: Gillis baseline hyperparameters, matching the host ``GillisDecider``
#: defaults: (eps0, lr, decay)
GILLIS_HP = (0.5, 0.3, 0.995)

#: layout of the packed per-substep metric accumulator (one dot per
#: substep): [n_fin, Σresp, n_viol, Σacc, Σreward, Σwait, fin_dec·3]
METRIC_COLS = ("n_fin", "sum_resp", "n_viol", "sum_acc", "sum_reward",
               "sum_wait", "fin_layer", "fin_semantic", "fin_compressed")


def _init_acc(n: int):
    f8 = jnp.float64
    return {
        "now": jnp.zeros((), f8),
        "energy": jnp.zeros((), f8),
        "pwt": jnp.zeros((n,), f8),
        "metrics": jnp.zeros((len(METRIC_COLS),), f8),
    }


def _resolve_substep_impl(substep_impl):
    """Resolve the substep execution strategy: an explicit argument wins,
    then the ``JAXSIM_SUBSTEP_IMPL`` environment variable (how the CI
    Pallas leg flips the whole suite), then the byte-stable ``"xla"``
    default."""
    impl = substep_impl or os.environ.get("JAXSIM_SUBSTEP_IMPL", "xla")
    if impl not in ("xla", "pallas", "ref"):
        raise ValueError(f"substep_impl={impl!r} "
                         "(want 'xla', 'pallas' or 'ref')")
    return impl


def _interval_physics(state, acc, bw_row, cl, substeps, dt, interval_s,
                      swap_slowdown, substep_impl):
    """Shared interval tail for every engine: waiting-time accounting,
    the substep physics, and the utilization → power → energy
    accumulation.  Engines differ only in their decide/place/feedback
    hooks around this.  Also returns the per-worker interval utilization
    (the AEC ingredient of the DASO training target, eq. 10)."""
    state = dict(state)
    state["wait_s"] = state["wait_s"] + jnp.where(
        state["alive"] & ~state["placed"], interval_s, 0.0)
    state, acc, busy = kernels.run_substeps(
        state, acc, bw_row, cl, substeps=substeps, dt=dt,
        swap_slowdown=swap_slowdown, impl=substep_impl)
    util = busy / interval_s
    power = cl["power_idle"] + (cl["power_peak"] - cl["power_idle"]) \
        * jnp.clip(util, 0.0, 1.0)
    acc = dict(acc)
    acc["energy"] = acc["energy"] + jnp.sum(power) * interval_s
    return state, acc, util


def _telemetry_base_row(state, acc, m0, e0, d0, util, fin):
    """One float64 row of the per-interval telemetry series (the
    ``metrics.TELEMETRY_COLS`` layout): interval deltas of the packed
    metric dot / drop counter / energy, finisher response & wait
    extremes, the per-worker utilization summary, and end-of-interval
    slot occupancy.  ``m0``/``e0``/``d0`` are the interval-entry
    snapshots the deltas subtract."""
    f8 = jnp.float64
    md = (acc["metrics"] - m0).astype(f8)
    have = md[0] > 0
    inf = jnp.asarray(jnp.inf, f8)
    resp, wait = state["resp"], state["wait_s"]
    rmin = jnp.where(have, jnp.min(jnp.where(fin, resp, inf)), 0.0)
    rmax = jnp.where(have, jnp.max(jnp.where(fin, resp, -inf)), 0.0)
    wmin = jnp.where(have, jnp.min(jnp.where(fin, wait, inf)), 0.0)
    wmax = jnp.where(have, jnp.max(jnp.where(fin, wait, -inf)), 0.0)
    extras = jnp.stack([
        (state["dropped"] - d0).astype(f8),
        (acc["energy"] - e0).astype(f8),
        rmin, rmax, wmin, wmax,
        jnp.mean(util).astype(f8), jnp.max(util).astype(f8),
        jnp.sum(state["alive"]).astype(f8),
    ])
    return jnp.concatenate([md, extras])


def _trace_program(engine, T, A, K, F, n, substeps, interval_s,
                   swap_slowdown, substep_impl="xla", telemetry="summary"):
    """THE interval program: one carry layout, one hook sequence, every
    policy.  ``engine`` is compile-time static (part of the cache key);
    its dynamic state rides the carry as ``es``.

    ``telemetry="interval"`` appends a preallocated ``(T, C)`` float64
    series to the fori_loop carry and writes one row per interval via
    ``dynamic_update_slice`` — the base ``metrics.TELEMETRY_COLS``
    columns plus the engine's ``telemetry_cols()``.  The default
    ``"summary"`` path is byte-identical to a build without the knob
    (the telemetry branch never traces), which is what keeps the golden
    fixtures valid unregenerated."""
    dt = interval_s / substeps
    tel = telemetry == "interval"
    if tel:
        n_cols = len(TELEMETRY_COLS) + len(tuple(engine.telemetry_cols()))

    def run_one(trace, cl, es0):
        state = kernels.init_state(K, F, n)
        acc = _init_acc(n)

        def interval(t, carry):
            state, acc, es = carry
            arr, es = engine.decide(es, trace, t)
            state = kernels.admit(state, arr)
            req, es, aux = engine.place(es, state, cl, trace, t, interval_s)
            state = kernels.apply_requests(state, cl, req)
            prev_done = state["task_done"]
            state, acc, util = _interval_physics(
                state, acc, trace["bw_mult"][t], cl, substeps, dt,
                interval_s, swap_slowdown, substep_impl)
            fin = state["task_done"] & ~prev_done
            es = engine.feedback(es, state, fin, util, aux, t, interval_s)
            state["alive"] = state["alive"] & ~state["task_done"]
            return state, acc, es

        def interval_tel(t, carry):
            # the same hook sequence as ``interval`` (kept verbatim above
            # so the summary path's trace is untouched), plus the
            # interval-entry snapshots and the end-of-interval row write
            state, acc, es, series = carry
            m0, e0, d0 = acc["metrics"], acc["energy"], state["dropped"]
            arr, es = engine.decide(es, trace, t)
            state = kernels.admit(state, arr)
            req, es, aux = engine.place(es, state, cl, trace, t, interval_s)
            state = kernels.apply_requests(state, cl, req)
            prev_done = state["task_done"]
            state, acc, util = _interval_physics(
                state, acc, trace["bw_mult"][t], cl, substeps, dt,
                interval_s, swap_slowdown, substep_impl)
            fin = state["task_done"] & ~prev_done
            es = engine.feedback(es, state, fin, util, aux, t, interval_s)
            state["alive"] = state["alive"] & ~state["task_done"]
            row = _telemetry_base_row(state, acc, m0, e0, d0, util, fin)
            erow = engine.telemetry_row(es)
            if erow is not None:
                row = jnp.concatenate([row, erow.astype(jnp.float64)])
            series = lax.dynamic_update_slice(series, row[None, :], (t, 0))
            return state, acc, es, series

        if tel:
            series0 = jnp.zeros((T, n_cols), jnp.float64)
            state, acc, es, series = lax.fori_loop(
                0, T, interval_tel, (state, acc, es0, series0))
        else:
            state, acc, es = lax.fori_loop(0, T, interval, (state, acc, es0))
        out = {"metrics": acc["metrics"], "energy": acc["energy"],
               "pwt": acc["pwt"], "dropped": state["dropped"]}
        if tel:
            out["telemetry"] = series
        out.update(engine.outputs(es))
        return out

    return run_one


def _static_key(engine, trace_leaves, K, n, substeps, interval_s,
                swap_slowdown, substep_impl, telemetry="summary"):
    """The runner-cache / compile key.  Shape-bearing dims are read off
    the fragment leaf (``vinstr`` for dual traces, ``instr`` for static
    ones); the engine itself carries every policy-side static.  The
    telemetry knob is compile-time static too: it changes the carry
    layout, so each mode is its own executable."""
    dual = "vinstr" in trace_leaves
    shp = trace_leaves["vinstr" if dual else "instr"].shape
    T, A, F = (shp[-4], shp[-3], shp[-1]) if dual else \
        (shp[-3], shp[-2], shp[-1])
    return (engine, T, A, K, F, n, substeps, interval_s, swap_slowdown,
            substep_impl, telemetry)


def _get_runner(key, batched: bool):
    ck = key + (batched,)
    hit = ck in _RUNNER_CACHE
    _note_cache(ck, hit)
    if not hit:
        engine = key[0]
        with get_ledger().span("compile", engine=engine.name,
                               batched=batched):
            prog = _trace_program(*key)
            if batched:
                prog = jax.vmap(prog,
                                in_axes=(0, None, engine.batch_axes()))
            _cache_put(ck, jax.jit(prog))
    return _cache_get(ck)


# ------------------------------------------------ streaming chunk program


class _ShiftedLeaf:
    """A chunk-local tape leaf indexed by the ABSOLUTE interval index.

    The streaming driver feeds the interval program fixed-size chunk
    tapes whose row 0 is absolute interval ``t0``, but engine hooks must
    see the global ``t`` — their ``fold_in(key, t)`` decision bits have
    to match the one-shot episode bit for bit.  Wrapping every leaf so
    ``leaf[t]`` reads row ``t - t0`` keeps the engine protocol unchanged
    (``trace[k][t]`` everywhere) while the tape stays chunk-sized."""

    __slots__ = ("arr", "t0")

    def __init__(self, arr, t0):
        self.arr = arr
        self.t0 = t0

    def __getitem__(self, t):
        return self.arr[t - self.t0]


def _stream_program(engine, T, A, K, F, n, substeps, interval_s,
                    swap_slowdown, substep_impl="xla"):
    """Carry-re-entrant chunk program for the streaming serve driver:
    the same hook sequence as ``_trace_program``'s telemetry body, but
    the carry ``(state, acc, es)`` enters as an ARGUMENT and leaves as a
    result, so consecutive ``chunk_intervals``-sized calls continue one
    endless episode (``T`` here is the chunk length — one compile per
    chunk shape).  ``t0`` is the chunk's absolute start interval, traced
    (not static) so every chunk shares the executable; the fori_loop
    runs over absolute indices and tape rows are shifted back via
    ``_ShiftedLeaf``.  The per-interval telemetry series is always on —
    it is the substrate of the serving layer's rolling metrics."""
    dt = interval_s / substeps
    n_cols = len(TELEMETRY_COLS) + len(tuple(engine.telemetry_cols()))

    def run_chunk(trace, cl, carry, t0):
        tr = {k: _ShiftedLeaf(v, t0) for k, v in trace.items()}

        def interval_tel(t, c):
            state, acc, es, series = c
            m0, e0, d0 = acc["metrics"], acc["energy"], state["dropped"]
            arr, es = engine.decide(es, tr, t)
            state = kernels.admit(state, arr)
            req, es, aux = engine.place(es, state, cl, tr, t, interval_s)
            state = kernels.apply_requests(state, cl, req)
            prev_done = state["task_done"]
            state, acc, util = _interval_physics(
                state, acc, tr["bw_mult"][t], cl, substeps, dt,
                interval_s, swap_slowdown, substep_impl)
            fin = state["task_done"] & ~prev_done
            es = engine.feedback(es, state, fin, util, aux, t, interval_s)
            state["alive"] = state["alive"] & ~state["task_done"]
            row = _telemetry_base_row(state, acc, m0, e0, d0, util, fin)
            erow = engine.telemetry_row(es)
            if erow is not None:
                row = jnp.concatenate([row, erow.astype(jnp.float64)])
            series = lax.dynamic_update_slice(series, row[None, :],
                                              (t - t0, 0))
            return state, acc, es, series

        state, acc, es = carry
        series0 = jnp.zeros((T, n_cols), jnp.float64)
        state, acc, es, series = lax.fori_loop(
            t0, t0 + T, interval_tel, (state, acc, es, series0))
        return (state, acc, es), series

    return run_chunk


def _get_stream_runner(key):
    """Compile-cached streaming chunk runner.  ``key`` is a
    ``_static_key(..., telemetry="stream")`` tuple — ``T`` in it is the
    chunk length, so a steady stream of equal-size chunks hits one
    executable forever.  The chunk-to-chunk carry (argument 2) is
    donated wherever the backend supports it: the slot/accumulator/
    engine-state arrays are updated in place instead of holding two
    copies across a 16k-interval soak."""
    hit = key in _RUNNER_CACHE
    _note_cache(key, hit)
    if not hit:
        engine = key[0]
        with get_ledger().span("compile", engine=engine.name, stream=True):
            prog = _stream_program(*key[:-1])
            donate = (2,) if _donation_ok() else ()
            _cache_put(key, jax.jit(prog, donate_argnums=donate))
    return _cache_get(key)


def _check_telemetry(engine, telemetry):
    """Validate the knob and resolve the full column tuple (base +
    engine learning-signal columns); None in summary mode."""
    if telemetry not in ("summary", "interval"):
        raise ValueError(f"telemetry={telemetry!r} "
                         "(want 'summary' or 'interval')")
    if telemetry == "summary":
        return None
    return tuple(TELEMETRY_COLS) + tuple(engine.telemetry_cols())


def _summarize(out, interval_s: float, n_intervals: int,
               cost_hr_total: float, telemetry_cols=None) -> dict:
    """Assemble the §6.4 summary dict (``MetricsAccumulator.summary``
    schema) from kernel accumulators.  With ``telemetry_cols`` (interval
    mode) the summary additionally carries the sliced per-interval
    series under ``"telemetry"`` plus host-side percentile estimates
    from it (see ``metrics.series_percentiles`` for the binning error
    bound reported as ``percentile_err_s``)."""
    m = dict(zip(METRIC_COLS, np.asarray(out["metrics"], np.float64)))
    n_fin = m["n_fin"]
    d = max(n_fin, 1.0)
    mean_resp = m["sum_resp"] / d
    mean_wait = m["sum_wait"] / d
    pwt = np.asarray(out["pwt"], np.float64)
    tot = pwt.sum()
    fair = float(tot ** 2 / (len(pwt) * np.sum(pwt ** 2) + 1e-12)) \
        if tot > 0 else 1.0
    cost = cost_hr_total * interval_s / 3600.0 * n_intervals
    s = {
        "accuracy": float(m["sum_acc"] / d),
        "sla_violations": float(m["n_viol"] / d),
        "reward": float(m["sum_reward"] / d),
        "response_intervals": float(mean_resp / interval_s),
        "wait_intervals": float(mean_wait / interval_s),
        "exec_intervals": float((mean_resp - mean_wait) / interval_s),
        "energy_mwhr": float(out["energy"]) / 3.6e9,
        "fairness": fair,
        "cost_per_container": float(cost / max(1, int(tot))),
        "layer_fraction": float(m["fin_layer"] / d),
        "tasks_completed": int(n_fin),
        "dropped_tasks": int(out["dropped"]),
    }
    if telemetry_cols is not None:
        # slice to the valid interval cells (padded grid rows were
        # already dropped by the caller's row loop)
        series = np.asarray(out["telemetry"], np.float64)[:n_intervals]
        s.update(series_percentiles(series, telemetry_cols))
        s["telemetry"] = {"cols": list(telemetry_cols), "series": series}
    return s


def _run_chunks(prepped):
    """Execute (runner, stacked-leaves) chunks, one thread per chunk:
    jitted XLA executions release the GIL, so chunks run on separate
    cores — parallelism the GIL-bound host interval loop cannot have.
    Results are independent per trace, so chunking changes nothing
    numerically."""
    led = get_ledger()
    # the span stack is thread-local, so pool threads attach their chunk
    # spans to the dispatch span via an explicit parent id
    parent = led.current_span()

    def run_chunk(irl):
        i, rl = irl
        with led.span("chunk", parent=parent, idx=i,
                      n_traces=int(rl[1]["valid"].shape[0])):
            with enable_x64():   # config contexts are thread-local
                return rl[0](rl[1])

    if len(prepped) == 1:
        outs = [run_chunk((0, prepped[0]))]
    else:
        with ThreadPoolExecutor(max_workers=len(prepped)) as ex:
            outs = list(ex.map(run_chunk, enumerate(prepped)))
    return [jax.tree_util.tree_map(np.asarray, o) for o in outs]


def _check_grid_homogeneous(traces):
    """Every grid cell must share the compile-time statics; the error
    names each offending cell so a mixed sweep is debuggable from the
    message alone."""
    sig = lambda t: (t.n_intervals, t.interval_s, t.substeps,
                     getattr(t, "variants", None))
    s0 = sig(traces[0])
    bad = [(i, sig(t)) for i, t in enumerate(traces) if sig(t) != s0]
    if bad:
        lines = "; ".join(
            f"trace[{i}] has (n_intervals, interval_s, substeps, "
            f"variants)={s}" for i, s in bad)
        raise ValueError(
            "grid cells must share n_intervals/interval_s/substeps/"
            "variants (shapes and decision codes are compile-time "
            f"static): trace[0] has {s0}, but {lines}")


def _grid_chunks(traces, threads):
    """Validate grid homogeneity and split it into thread chunks."""
    # checked here, not just inside per-chunk stack_traces: chunking
    # could otherwise split mismatched traces into separate chunks
    # and silently run them under traces[0]'s compiled physics (or,
    # for variants, the wrong decision codes)
    _check_grid_homogeneous(traces)
    if threads is None:
        threads = max(1, min(os.cpu_count() or 1, len(traces) // 2))
    threads = max(1, min(threads, len(traces)))
    per = -(-len(traces) // threads)
    return [list(traces[i:i + per]) for i in range(0, len(traces), per)]


# ------------------------------------------------ sharded grid dispatch


def _es_shard_spec(axes):
    """shard_map spec prefix for the engine-state pytree, derived from
    the same ``batch_axes()`` prefix vmap consumes: per-cell leaves
    (axis 0) shard over the grid mesh axis, shared starting state
    replicates."""
    from jax.sharding import PartitionSpec as P
    if axes is None:
        return P()
    if axes == 0:
        return P("grid")
    if isinstance(axes, dict):
        return {k: _es_shard_spec(v) for k, v in axes.items()}
    raise ValueError(f"unsupported engine batch axis {axes!r}")


def _get_sharded_runner(key, mesh):
    """``jit(shard_map(vmap(program)))`` over the 1-D grid mesh: every
    device runs the vmapped interval program on its contiguous slice of
    the stacked-trace axis.  Trace leaves and per-cell engine-state
    leaves shard over ``"grid"``; cluster rows and shared engine state
    replicate.  The trace-leaf and engine-state carries are donated
    wherever the backend's donation probe passes (``_donation_ok`` —
    accelerators always, XLA:CPU on the jaxlib builds that actually
    support donation)."""
    d = int(np.prod(mesh.devices.shape))
    ck = key + ("smap", d)
    hit = ck in _RUNNER_CACHE
    _note_cache(ck, hit)
    if not hit:
        from jax.sharding import PartitionSpec as P
        if hasattr(jax, "shard_map"):            # jax >= 0.6
            smap = jax.shard_map
        else:
            from jax.experimental.shard_map import shard_map as smap
        engine = key[0]
        with get_ledger().span("compile", engine=engine.name,
                               sharded=True, mesh=d):
            prog = jax.vmap(_trace_program(*key),
                            in_axes=(0, None, engine.batch_axes()))
            # the interval program's while/fori loops have no shard_map
            # replication rule — skip the rep check (cells are
            # independent, nothing cross-device to validate); kwarg name
            # varies by version
            import inspect
            chk = {p: False for p in ("check_rep", "check_vma")
                   if p in inspect.signature(smap).parameters}
            sharded = smap(prog, mesh=mesh,
                           in_specs=(P("grid"), P(),
                                     _es_shard_spec(engine.batch_axes())),
                           out_specs=P("grid"), **chk)
            donate = (0, 2) if _donation_ok() else ()
            _cache_put(ck, jax.jit(sharded, donate_argnums=donate))
    return _cache_get(ck)


def _run_grid_sharded(engine, traces, es_builder, cl, cld, K,
                      swap_slowdown, substep_impl, devices,
                      telemetry="summary"):
    """One shard_map call over the whole grid (no thread chunking).

    The grid is padded up to a multiple of the mesh size by replicating
    the last trace and masking its arrivals invalid — dead cells admit
    no tasks, so their interval program runs an empty system and their
    output rows are discarded.  Returns the stacked (padded) output
    tree as NumPy; the caller slices the first ``len(traces)`` rows."""
    from repro.launch.mesh import make_grid_mesh
    mesh = make_grid_mesh(devices)
    d = int(np.prod(mesh.devices.shape))
    t0, G = traces[0], len(traces)
    pad = (-G) % d
    padded = list(traces) + [traces[-1]] * pad
    A = max(t.max_arrivals for t in traces)
    F = max(t.max_frags for t in traces)
    leaves = {k: jnp.asarray(v)
              for k, v in stack_traces(padded, max_arrivals=A,
                                       max_frags=F).items()}
    if pad:
        leaves["valid"] = leaves["valid"].at[G:].set(False)
    # the sharded runner donates the engine-state argument; es_builder
    # may hand back device arrays the caller still holds (shared
    # pretrained theta, carried MAB scalars), so copy instead of
    # aliasing — donation must only consume buffers this call owns
    es0 = jax.tree_util.tree_map(lambda v: jnp.array(v, copy=True),
                                 es_builder(padded))
    key = _static_key(engine, leaves, K, cl.n, t0.substeps, t0.interval_s,
                      swap_slowdown, substep_impl, telemetry)
    runner = _get_sharded_runner(key, mesh)
    with get_ledger().span("dispatch", engine=engine.name, sharded=True,
                           n_traces=G, mesh=d):
        out = runner(leaves, cld, es0)
        return jax.tree_util.tree_map(np.asarray, out)


# ------------------------------------------------- generic engine runners


def run_trace_engine(engine, trace, es0, cluster: Optional[Cluster] = None,
                     max_active: Optional[int] = None,
                     swap_slowdown: float = 0.5,
                     substep_impl: Optional[str] = None,
                     telemetry: str = "summary") -> dict:
    """Run one compiled trace through the unified interval program under
    ``engine``, starting its carried state from ``es0``.

    ``telemetry="interval"`` additionally records the per-interval
    telemetry series in the carry and attaches it (plus percentile
    estimates) to the summary; ``"summary"`` compiles the exact program
    this driver has always run."""
    tcols = _check_telemetry(engine, telemetry)
    led = get_ledger()
    cluster = cluster or make_cluster()
    cl = ClusterArrays.from_cluster(cluster)
    K = max_active or default_capacity([trace])
    impl = _resolve_substep_impl(substep_impl)
    with enable_x64():
        leaves = {k: jnp.asarray(v) for k, v in trace.kernel_dict().items()}
        cld = {k: jnp.asarray(v) for k, v in cl.as_dict().items()}
        es0 = jax.tree_util.tree_map(jnp.asarray, es0)
        key = _static_key(engine, leaves, K, cl.n, trace.substeps,
                          trace.interval_s, swap_slowdown, impl, telemetry)
        runner = _get_runner(key, batched=False)
        with led.span("dispatch", engine=engine.name, n_traces=1,
                      telemetry=telemetry):
            out = jax.tree_util.tree_map(np.asarray,
                                         runner(leaves, cld, es0))
    with led.span("summarize", engine=engine.name, n_traces=1):
        return engine.summarize(out, _summarize(
            out, trace.interval_s, trace.n_intervals,
            float(cl.cost_hr.sum()), telemetry_cols=tcols))


def run_grid_engine(engine, traces, es_builder: Callable,
                    cluster: Optional[Cluster] = None,
                    max_active: Optional[int] = None,
                    swap_slowdown: float = 0.5,
                    threads: Optional[int] = None,
                    devices=None,
                    substep_impl: Optional[str] = None,
                    telemetry: str = "summary") -> list:
    """Run a whole grid of compiled traces through the jitted vmapped
    engine program; returns one summary dict per trace (same order).

    ``es_builder(chunk)`` produces the engine-state pytree for one trace
    chunk (shared leaves + any per-cell leaves like PRNG keys, marked by
    ``engine.batch_axes()``); it runs inside the driver's ``enable_x64``
    scope so float64 state construction is safe.

    Dispatch is two-mode.  Default (``devices=None``): the grid is split
    into ``threads`` equal vmap chunks dispatched from a thread pool —
    jitted XLA executions release the GIL, so chunks run on separate
    cores; ``threads`` defaults to the core count (capped by the grid
    size); pass 1 to force a single call.  ``devices="auto"`` (or an
    int): one ``shard_map`` call over a 1-D device mesh instead — the
    grid is padded to a mesh multiple with masked dead cells and every
    device runs its contiguous slice (``_run_grid_sharded``).  Results
    are independent per trace, so neither chunking nor sharding changes
    anything numerically.
    """
    tcols = _check_telemetry(engine, telemetry)
    led = get_ledger()
    cluster = cluster or make_cluster()
    cl = ClusterArrays.from_cluster(cluster)
    K = max_active or default_capacity(traces)
    t0 = traces[0]
    impl = _resolve_substep_impl(substep_impl)
    if devices is not None:
        _check_grid_homogeneous(traces)
        with enable_x64():
            cld = {k: jnp.asarray(v) for k, v in cl.as_dict().items()}
            out = _run_grid_sharded(engine, traces, es_builder, cl, cld,
                                    K, swap_slowdown, impl, devices,
                                    telemetry)
        # one padded output tree; the summary loop below walks only the
        # first len(traces) rows, dropping the dead padding cells
        chunks, outs = [list(traces)], [out]
    else:
        chunks = _grid_chunks(traces, threads)
        with enable_x64():
            cld = {k: jnp.asarray(v) for k, v in cl.as_dict().items()}
            A = max(t.max_arrivals for t in traces)
            F = max(t.max_frags for t in traces)

            def prep(chunk):
                leaves = {k: jnp.asarray(v)
                          for k, v in stack_traces(chunk, max_arrivals=A,
                                                   max_frags=F).items()}
                es0 = jax.tree_util.tree_map(jnp.asarray, es_builder(chunk))
                key = _static_key(engine, leaves, K, cl.n, t0.substeps,
                                  t0.interval_s, swap_slowdown, impl,
                                  telemetry)
                runner = _get_runner(key, batched=True)
                # bind the per-chunk engine state so _run_chunks' (runner,
                # leaves) calling convention is engine-agnostic
                return (lambda l, r_=runner, e_=es0: r_(l, cld, e_)), leaves

            # compile (cached) before parallel dispatch so threads only
            # race on execution, never on tracing
            prepped = [prep(c) for c in chunks]
            with led.span("dispatch", engine=engine.name,
                          n_traces=len(traces), n_chunks=len(chunks),
                          telemetry=telemetry):
                outs = _run_chunks(prepped)
    cost_total = float(cl.cost_hr.sum())
    results = []
    with led.span("summarize", engine=engine.name, n_traces=len(traces)):
        for chunk, out in zip(chunks, outs):
            for i, _ in enumerate(chunk):
                row = jax.tree_util.tree_map(
                    lambda v: v[i] if np.ndim(v) > 0 else v, out)
                results.append(engine.summarize(row, _summarize(
                    row, t0.interval_s, t0.n_intervals, cost_total,
                    telemetry_cols=tcols)))
    return results


# ------------------------------------------------ engine-state assembly


def _check_variants(traces, expected):
    """A dual trace's V axis must realize the decision codes the engine
    decides between — an MAB trace fed to the Gillis engine (or vice
    versa) would mislabel fragments as the wrong split."""
    for t in traces:
        got = tuple(getattr(t, "variants", (0, 1)))
        if got != tuple(expected):
            raise ValueError(
                f"trace realizes variants {got}, engine needs "
                f"{tuple(expected)} (compile_trace_dual(variants=...))")


def _check_learned_args(daso_cfg, daso_theta, n):
    if daso_cfg is None:
        return ()                         # BestFit placement: no surrogate
    if daso_theta is None:
        raise ValueError("the DASO placer needs pretrained theta "
                         "(see launch.experiments.pretrain)")
    if daso_cfg.num_workers != n:
        raise ValueError(f"daso_cfg.num_workers={daso_cfg.num_workers} "
                         f"!= cluster size {n}")
    return daso_theta


def _trained_opt_state(daso_cfg, theta, daso_opt_state):
    """The AdamW state the training carry starts from — fresh zeros when
    the caller didn't hand over the pretraining optimizer moments."""
    if daso_cfg is None:
        return ()
    from repro.optim.optimizers import adamw_init
    if daso_opt_state is None:
        return adamw_init(theta)
    return daso_opt_state


def trace_train_key(seed: int):
    """The per-trace decision PRNG key of the in-kernel training and
    Gillis loops — shared with ``reference.replay_trace_edgesim_trained``
    / ``replay_trace_edgesim_gillis`` so both backends draw identical
    ε-greedy bits."""
    return jax.random.PRNGKey(seed)


def _deploy_es(mab_state, theta):
    return {"mab": mab_state, "theta": theta}


def _train_es(daso_cfg, mab_state, theta, daso_opt_state, keys):
    """Training-carry starting state; built under ``enable_x64`` so the
    replay window is float64 like the in-carry appends."""
    with enable_x64():
        import repro.core.daso as daso_mod
        win = daso_mod.window_init(daso_cfg) if daso_cfg is not None else {}
        opt = _trained_opt_state(daso_cfg, theta, daso_opt_state)
    return {"mab": mab_state, "theta": theta, "opt": opt, "win": win,
            "key": keys}


def gillis_layer_ref(num_apps: int = 3):
    """The (num_apps,) unloaded layer-chain reference table the Gillis
    context bucket divides deadlines by (``mab.gillis_bucket``) — built
    once here so the kernel engine and the host parity oracle consume
    the identical float64 values."""
    from repro.env.workload import layer_ref_response_s
    return np.array([layer_ref_response_s(a) for a in range(num_apps)],
                    np.float64)


def gillis_init_state(num_apps: int = 3, eps0: float = GILLIS_HP[0]):
    """Fresh host-side Gillis carry pieces (Q-table + ε) — NumPy float64
    so the driver's ``enable_x64`` asarray keeps full precision.  Pass a
    previous run's ``{"Q": gillis_q, "eps": gillis_eps}`` instead to
    continue a pretrained baseline."""
    return {"Q": np.zeros((num_apps, 2, 2), np.float64),
            "eps": np.float64(eps0)}


def _gillis_es(gillis_state, keys, num_apps: int, eps0: float):
    st = gillis_state or gillis_init_state(num_apps, eps0)
    return {"Q": np.asarray(st["Q"], np.float64),
            "eps": np.float64(st["eps"]), "key": keys,
            "layer_ref": gillis_layer_ref(num_apps)}


# ------------------------------------------------- engine-selecting API
#
# Thin wrappers that pick an engine + assemble its starting state; every
# one funnels into run_trace_engine / run_grid_engine above.  Kept for
# API stability (benchmarks, experiments, tests) — there is exactly one
# interval-program family behind them.


def run_grid_arrays(traces: Sequence[TraceArrays],
                    cluster: Optional[Cluster] = None,
                    max_active: Optional[int] = None,
                    swap_slowdown: float = 0.5,
                    threads: Optional[int] = None,
                    devices=None,
                    substep_impl: Optional[str] = None,
                    telemetry: str = "summary") -> list:
    """Run a grid of statically-decided compiled traces (BestFit
    placement); returns one §6.4 summary dict per trace."""
    return run_grid_engine(engines.StaticEngine(), traces,
                           lambda chunk: (), cluster=cluster,
                           max_active=max_active,
                           swap_slowdown=swap_slowdown, threads=threads,
                           devices=devices, substep_impl=substep_impl,
                           telemetry=telemetry)


def run_trace_arrays(trace: TraceArrays, cluster: Optional[Cluster] = None,
                     max_active: Optional[int] = None,
                     swap_slowdown: float = 0.5,
                     substep_impl: Optional[str] = None,
                     telemetry: str = "summary") -> dict:
    """Run one compiled trace through the (unbatched) static program."""
    return run_trace_engine(engines.StaticEngine(), trace, (),
                            cluster=cluster, max_active=max_active,
                            swap_slowdown=swap_slowdown,
                            substep_impl=substep_impl,
                            telemetry=telemetry)


def run_grid_arrays_learned(traces: Sequence[DualTraceArrays], mab_state,
                            daso_theta=None, daso_cfg=None,
                            cluster: Optional[Cluster] = None,
                            max_active: Optional[int] = None,
                            swap_slowdown: float = 0.5,
                            threads: Optional[int] = None,
                            devices=None,
                            substep_impl: Optional[str] = None,
                            telemetry: str = "summary",
                            mab_hp=MAB_HP) -> list:
    """Run a grid of dual traces under the in-kernel deploy-mode learned
    policy — online UCB MAB split decisions, plus the array-form DASO
    placer when ``daso_cfg``/``daso_theta`` are given (BestFit
    otherwise; ``daso_cfg.decision_aware=False`` is the GOBI ablation).

    Every grid cell carries its own copy of ``mab_state`` through the
    interval loop (the pretrained state is the shared starting point, the
    online feedback trajectories diverge per cell).  Returns one summary
    dict per trace extended with the final MAB scalars
    (``mab_eps``/``mab_rho``/``mab_t``)."""
    _check_variants(traces, engines.MAB_VARIANTS)
    cluster = cluster or make_cluster()
    theta = _check_learned_args(daso_cfg, daso_theta, cluster.n)
    engine = engines.MABDeployEngine(mab_hp=tuple(mab_hp),
                                     daso_cfg=daso_cfg)
    return run_grid_engine(engine, traces,
                           lambda chunk: _deploy_es(mab_state, theta),
                           cluster=cluster, max_active=max_active,
                           swap_slowdown=swap_slowdown, threads=threads,
                           devices=devices, substep_impl=substep_impl,
                           telemetry=telemetry)


def run_trace_arrays_learned(trace: DualTraceArrays, mab_state,
                             daso_theta=None, daso_cfg=None,
                             cluster: Optional[Cluster] = None,
                             max_active: Optional[int] = None,
                             swap_slowdown: float = 0.5,
                             substep_impl: Optional[str] = None,
                             telemetry: str = "summary",
                             mab_hp=MAB_HP) -> dict:
    """Run one dual trace through the (unbatched) deploy-mode program."""
    _check_variants([trace], engines.MAB_VARIANTS)
    cluster = cluster or make_cluster()
    theta = _check_learned_args(daso_cfg, daso_theta, cluster.n)
    engine = engines.MABDeployEngine(mab_hp=tuple(mab_hp),
                                     daso_cfg=daso_cfg)
    return run_trace_engine(engine, trace, _deploy_es(mab_state, theta),
                            cluster=cluster, max_active=max_active,
                            swap_slowdown=swap_slowdown,
                            substep_impl=substep_impl,
                            telemetry=telemetry)


def run_grid_arrays_trained(traces: Sequence[DualTraceArrays], mab_state,
                            daso_theta=None, daso_cfg=None,
                            daso_opt_state=None,
                            cluster: Optional[Cluster] = None,
                            max_active: Optional[int] = None,
                            swap_slowdown: float = 0.5,
                            threads: Optional[int] = None,
                            devices=None,
                            substep_impl: Optional[str] = None,
                            telemetry: str = "summary",
                            mab_hp=MAB_HP, train_hp=TRAIN_HP) -> list:
    """Run a grid of dual traces with the FULL training loop in-kernel:
    ε-greedy MAB decisions + Algorithm-1 feedback, and (when
    ``daso_cfg``/``daso_theta`` are given) online DASO finetuning —
    replay-window appends and ``train_epoch_weighted`` steps inside the
    jitted interval program.

    Every grid cell carries its own copies of ``mab_state`` and the
    DASO trainer (theta, opt_state, replay window); per-cell decision
    randomness comes from ``trace_train_key(trace.seed)``.  Summaries
    gain the final MAB scalars and (DASO runs) the finetuned ``theta``
    pytree under ``"daso_theta"``."""
    _check_variants(traces, engines.MAB_VARIANTS)
    cluster = cluster or make_cluster()
    theta = _check_learned_args(daso_cfg, daso_theta, cluster.n)
    engine = engines.MABTrainEngine(mab_hp=tuple(mab_hp),
                                    train_hp=tuple(train_hp),
                                    daso_cfg=daso_cfg)

    def es_builder(chunk):
        keys = jnp.stack([trace_train_key(t.seed) for t in chunk])
        return _train_es(daso_cfg, mab_state, theta, daso_opt_state, keys)

    return run_grid_engine(engine, traces, es_builder, cluster=cluster,
                           max_active=max_active,
                           swap_slowdown=swap_slowdown, threads=threads,
                           devices=devices, substep_impl=substep_impl,
                           telemetry=telemetry)


def run_trace_arrays_trained(trace: DualTraceArrays, mab_state,
                             daso_theta=None, daso_cfg=None,
                             daso_opt_state=None,
                             cluster: Optional[Cluster] = None,
                             max_active: Optional[int] = None,
                             swap_slowdown: float = 0.5,
                             substep_impl: Optional[str] = None,
                             telemetry: str = "summary",
                             mab_hp=MAB_HP, train_hp=TRAIN_HP) -> dict:
    """Run one dual trace through the (unbatched) in-kernel training
    program."""
    _check_variants([trace], engines.MAB_VARIANTS)
    cluster = cluster or make_cluster()
    theta = _check_learned_args(daso_cfg, daso_theta, cluster.n)
    engine = engines.MABTrainEngine(mab_hp=tuple(mab_hp),
                                    train_hp=tuple(train_hp),
                                    daso_cfg=daso_cfg)
    es0 = _train_es(daso_cfg, mab_state, theta, daso_opt_state,
                    trace_train_key(trace.seed))
    return run_trace_engine(engine, trace, es0, cluster=cluster,
                            max_active=max_active,
                            swap_slowdown=swap_slowdown,
                            substep_impl=substep_impl,
                            telemetry=telemetry)


#: the three static-decider baseline arms of Table 4 and the
#: ``engines.MAB_VARIANTS`` index each realizes every row (−1 = uniform
#: random per row, the ``random+daso`` arm)
STATIC_DASO_ARMS = {"layer+gobi": 0, "semantic+gobi": 1, "random+daso": -1}


def _static_daso_engine(policy, daso_cfg, daso_theta, cluster):
    """Resolve one of the ``STATIC_DASO_ARMS`` into its engine + frozen
    theta.  The GOBI arms flip ``decision_aware=False`` here (the
    surrogate input's decision one-hot slice is zeroed — the host
    ``SurrogatePlacer(decision_aware=False)`` ablation); ``random+daso``
    keeps the caller's decision-aware cfg."""
    if policy not in STATIC_DASO_ARMS:
        raise ValueError(f"policy {policy!r} is not one of "
                         f"{sorted(STATIC_DASO_ARMS)}")
    if daso_cfg is None:
        raise ValueError(f"{policy!r} needs a pretrained DASO surrogate "
                         "(daso_cfg/daso_theta; see "
                         "launch.experiments.pretrain)")
    arm = STATIC_DASO_ARMS[policy]
    if arm >= 0:
        daso_cfg = daso_cfg._replace(decision_aware=False)
    theta = _check_learned_args(daso_cfg, daso_theta, cluster.n)
    engine = engines.StaticDeciderDASOEngine(arm=arm, daso_cfg=daso_cfg,
                                             name=policy)
    return engine, theta, arm


def run_grid_arrays_static_daso(traces: Sequence[DualTraceArrays],
                                policy: str, daso_theta=None,
                                daso_cfg=None,
                                cluster: Optional[Cluster] = None,
                                max_active: Optional[int] = None,
                                swap_slowdown: float = 0.5,
                                threads: Optional[int] = None,
                                devices=None,
                                substep_impl: Optional[str] = None,
                                telemetry: str = "summary") -> list:
    """Run a grid of dual traces under one of the static-decider baseline
    arms — ``layer+gobi`` / ``semantic+gobi`` (fixed split + decision-
    blind surrogate placement) or ``random+daso`` (uniform-random split +
    decision-aware surrogate placement).  Per-cell decision randomness
    for the random arm comes from ``trace_train_key(trace.seed)``;
    returns one §6.4 summary dict per trace."""
    _check_variants(traces, engines.MAB_VARIANTS)
    cluster = cluster or make_cluster()
    engine, theta, arm = _static_daso_engine(policy, daso_cfg, daso_theta,
                                             cluster)

    def es_builder(chunk):
        es = {"theta": theta}
        if arm < 0:
            es["key"] = jnp.stack([trace_train_key(t.seed) for t in chunk])
        return es

    return run_grid_engine(engine, traces, es_builder, cluster=cluster,
                           max_active=max_active,
                           swap_slowdown=swap_slowdown, threads=threads,
                           devices=devices, substep_impl=substep_impl,
                           telemetry=telemetry)


def run_trace_arrays_static_daso(trace: DualTraceArrays, policy: str,
                                 daso_theta=None, daso_cfg=None,
                                 cluster: Optional[Cluster] = None,
                                 max_active: Optional[int] = None,
                                 swap_slowdown: float = 0.5,
                                 substep_impl: Optional[str] = None,
                                 telemetry: str = "summary") -> dict:
    """Run one dual trace through the (unbatched) static-decider
    baseline-arm program (see ``run_grid_arrays_static_daso``)."""
    _check_variants([trace], engines.MAB_VARIANTS)
    cluster = cluster or make_cluster()
    engine, theta, arm = _static_daso_engine(policy, daso_cfg, daso_theta,
                                             cluster)
    es0 = {"theta": theta}
    if arm < 0:
        es0["key"] = trace_train_key(trace.seed)
    return run_trace_engine(engine, trace, es0, cluster=cluster,
                            max_active=max_active,
                            swap_slowdown=swap_slowdown,
                            substep_impl=substep_impl,
                            telemetry=telemetry)


def run_grid_arrays_gillis(traces: Sequence[DualTraceArrays],
                           gillis_state=None,
                           cluster: Optional[Cluster] = None,
                           max_active: Optional[int] = None,
                           swap_slowdown: float = 0.5,
                           threads: Optional[int] = None,
                           devices=None,
                           substep_impl: Optional[str] = None,
                           telemetry: str = "summary",
                           gillis_hp=GILLIS_HP, num_apps: int = 3) -> list:
    """Run a grid of LAYER/COMPRESSED dual traces under the in-kernel
    Gillis baseline — contextual ε-greedy Q-learning with per-interval
    ε-decay and per-leaving-task TD(0) updates, entirely in the carry.

    Traces must be compiled with ``compile_trace_dual(variants=(LAYER,
    COMPRESSED))``.  Every cell carries its own (Q, ε) copy from
    ``gillis_state`` (fresh zeros/ε₀ when None); per-cell randomness
    comes from ``trace_train_key(trace.seed)``.  Summaries gain
    ``gillis_eps`` and the final Q-table under ``"gillis_q"``."""
    _check_variants(traces, engines.GILLIS_VARIANTS)
    engine = engines.GillisEngine(gillis_hp=tuple(gillis_hp))

    def es_builder(chunk):
        keys = jnp.stack([trace_train_key(t.seed) for t in chunk])
        return _gillis_es(gillis_state, keys, num_apps, gillis_hp[0])

    return run_grid_engine(engine, traces, es_builder, cluster=cluster,
                           max_active=max_active,
                           swap_slowdown=swap_slowdown, threads=threads,
                           devices=devices, substep_impl=substep_impl,
                           telemetry=telemetry)


def run_trace_arrays_gillis(trace: DualTraceArrays, gillis_state=None,
                            cluster: Optional[Cluster] = None,
                            max_active: Optional[int] = None,
                            swap_slowdown: float = 0.5,
                            substep_impl: Optional[str] = None,
                            telemetry: str = "summary",
                            gillis_hp=GILLIS_HP, num_apps: int = 3) -> dict:
    """Run one LAYER/COMPRESSED dual trace through the (unbatched)
    in-kernel Gillis program."""
    _check_variants([trace], engines.GILLIS_VARIANTS)
    engine = engines.GillisEngine(gillis_hp=tuple(gillis_hp))
    es0 = _gillis_es(gillis_state, trace_train_key(trace.seed), num_apps,
                     gillis_hp[0])
    return run_trace_engine(engine, trace, es0, cluster=cluster,
                            max_active=max_active,
                            swap_slowdown=swap_slowdown,
                            substep_impl=substep_impl,
                            telemetry=telemetry)
