"""PolicyEngine zoo — the composable policy layer of the unified
interval program.

``driver._trace_program`` runs ONE interval pipeline for every policy:

    arr, es  = engine.decide(es, trace, t)          # split decisions
    state    = kernels.admit(state, arr)
    req, es, aux = engine.place(es, state, cl, trace, t, interval_s)
    state    = kernels.apply_requests(state, cl, req)
    ... physics (kernels.run_substeps) ...
    es       = engine.feedback(es, state, fin, util, aux, t, interval_s)

with a single carry layout ``(state, acc, engine_state)``.  An engine is
a **frozen, hashable** dataclass of static configuration — it is part of
the runner-cache key, so two calls with equal engines share one compiled
executable — and its ``engine_state`` (``es``) is an ordinary dynamic
pytree threaded through the ``fori_loop`` carry (MAB state, surrogate
theta, optimizer moments, replay window, PRNG key, Gillis Q-table…).

Protocol (duck-typed; every engine below implements it):

  * ``batch_axes()``  — vmap ``in_axes`` prefix for ``es`` under the
    batched grid runner (``0`` for per-cell leaves like PRNG keys,
    ``None`` for shared starting state);
  * ``decide(es, trace, t) -> (arr, es)`` — the admit-ready arrival
    dict for interval ``t`` (static engines slice the pre-realized
    trace; learned engines decide + ``select_variant`` a dual trace);
  * ``place(es, state, cl, trace, t, interval_s) -> (req, es, aux)`` —
    the (K, F) worker-request matrix ``apply_requests`` repairs;
    ``aux`` carries intra-interval data from place to feedback (the
    train engine's packed surrogate input);
  * ``feedback(es, state, fin, util, aux, t, interval_s) -> es`` —
    end-of-interval learning over the finished-slot mask + per-worker
    utilization;
  * ``outputs(es) -> dict`` — extra kernel outputs appended to the raw
    result (final MAB scalars, finetuned theta, Gillis Q/ε);
  * ``summarize(out, summary) -> summary`` — host-side: lift those
    extras into the §6.4 summary dict;
  * ``telemetry_cols() -> tuple[str, ...]`` / ``telemetry_row(es) ->
    jnp.ndarray | None`` — the engine's per-interval learning-signal
    columns for the driver's ``telemetry="interval"`` series (appended
    after ``metrics.TELEMETRY_COLS``); ``telemetry_row`` returns a
    float64 vector matching ``telemetry_cols`` evaluated on the
    END-of-interval ``es`` (after feedback), or ``None`` when the
    engine has no columns.

Adding a policy = adding one engine here (plus its host parity oracle
in ``reference.py``); the driver, runner cache, chunk dispatcher and
summary path are shared and untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import daso as daso_mod
from repro.core.daso import DASOConfig
from repro.env.jaxsim import kernels
from repro.env.workload import COMPRESSED, LAYER, SEMANTIC

#: arrival keys of a single-variant (static) compiled trace
STATIC_ARR_KEYS = ("valid", "sla", "arrival_s", "app", "batch", "acc",
                   "decision", "chain", "nfrag", "instr", "ram",
                   "out_bytes")
#: variant-independent / per-variant keys of a dual compiled trace
SHARED_KEYS = ("valid", "sla", "arrival_s", "app", "batch")
VAR_KEYS = ("vacc", "vchain", "vnfrag", "vinstr", "vram", "vout")

#: the dual-trace variant codes each engine family decides between
MAB_VARIANTS = (LAYER, SEMANTIC)
GILLIS_VARIANTS = (LAYER, COMPRESSED)


def _interval_rows(trace, t):
    shared = {k: trace[k][t] for k in SHARED_KEYS}
    var = {k: trace[k][t] for k in VAR_KEYS}
    return shared, var


def _mab_scalars(out, s):
    s["mab_eps"] = float(out["mab_eps"])
    s["mab_rho"] = float(out["mab_rho"])
    s["mab_t"] = int(out["mab_t"])
    return s


#: per-interval learning-signal columns shared by both MAB engines:
#: exploration/threshold scalars plus cumulative per-arm decision counts
#: (summed over the two SLA contexts)
MAB_TELEMETRY_COLS = ("mab_eps", "mab_rho", "mab_n_layer",
                      "mab_n_semantic")


def _mab_telemetry_row(mab):
    f8 = jnp.float64
    return jnp.stack([mab.eps.astype(f8), mab.rho.astype(f8),
                      mab.N[:, 0].sum().astype(f8),
                      mab.N[:, 1].sum().astype(f8)])


@dataclasses.dataclass(frozen=True)
class StaticEngine:
    """Pre-realized split decisions + BestFit placement; ``es`` is
    empty.  The trace carries one realized variant per task, so decide
    is a pure slice of the compiled arrays."""

    name: str = "static"

    def batch_axes(self):
        return None

    def decide(self, es, trace, t):
        return {k: trace[k][t] for k in STATIC_ARR_KEYS}, es

    def place(self, es, state, cl, trace, t, interval_s):
        return kernels.bestfit_requests(state, cl), es, None

    def feedback(self, es, state, fin, util, aux, t, interval_s):
        return es

    def outputs(self, es):
        return {}

    def summarize(self, out, s):
        return s

    def telemetry_cols(self):
        return ()

    def telemetry_row(self, es):
        return None


@dataclasses.dataclass(frozen=True)
class StaticDeciderDASOEngine:
    """The three remaining trivial Table-4 baseline arms as ONE engine:
    a static split decider over a dual (LAYER, SEMANTIC) trace plus the
    array-form DASO placement stage ascending a *frozen* pretrained
    surrogate.  ``arm`` picks the variant index every row — 0 for
    ``layer+gobi``, 1 for ``semantic+gobi``, or −1 for uniform-random
    rows (``random+daso``, per-interval fold-in bits like the train/
    Gillis engines; same algorithm as the host ``RandomDecider``,
    different bitstream).  The GOBI arms pass a
    ``decision_aware=False`` cfg — the surrogate input's decision
    one-hot slice is zeroed (``daso.pack_input``), mirroring the host
    ``SurrogatePlacer(decision_aware=False)``.  ``es = {"theta":
    pytree}`` (+ per-cell ``"key"`` for the random arm)."""

    arm: int
    daso_cfg: DASOConfig
    name: str = "static-daso"

    def batch_axes(self):
        if self.arm < 0:
            return {"theta": None, "key": 0}
        return None

    def decide(self, es, trace, t):
        shared, var = _interval_rows(trace, t)
        A = shared["sla"].shape[0]
        if self.arm < 0:
            # per-row fold-in (not one batched draw): row r's bit depends
            # only on (key, t, r), so the host replay walking the dense
            # valid prefix draws identical bits regardless of A padding
            key_t = jax.random.fold_in(es["key"], t)
            d = jax.vmap(lambda r: jax.random.bernoulli(
                jax.random.fold_in(key_t, r)))(
                    jnp.arange(A, dtype=jnp.int32)).astype(jnp.int32)
        else:
            d = jnp.full((A,), self.arm, jnp.int32)
        return kernels.select_variant(shared, var, d), es

    def place(self, es, state, cl, trace, t, interval_s):
        req = kernels.bestfit_requests(state, cl)
        feat = kernels.state_features_k(state, cl, trace["lat_prev"][t],
                                        interval_s)
        req = kernels.daso_requests(self.daso_cfg, es["theta"], state,
                                    feat, req)
        return req, es, None

    def feedback(self, es, state, fin, util, aux, t, interval_s):
        return es

    def outputs(self, es):
        return {}

    def summarize(self, out, s):
        return s

    def telemetry_cols(self):
        return ()

    def telemetry_row(self, es):
        return None


@dataclasses.dataclass(frozen=True)
class MABDeployEngine:
    """Online UCB MAB decisions (eq. 9) + Algorithm-1 feedback against
    the carried ``MABState``; optional array-form DASO placement stage
    ascending a *frozen* pretrained surrogate.  ``decision_aware=False``
    in ``daso_cfg`` is the GOBI ablation — the surrogate input's
    decision one-hot slice is zeroed (``daso.pack_input``), everything
    else identical.  ``es = {"mab": MABState, "theta": pytree | ()}``."""

    mab_hp: Tuple[float, float, float, float]
    daso_cfg: Optional[DASOConfig] = None
    name: str = "mab-deploy"

    def batch_axes(self):
        return None

    def decide(self, es, trace, t):
        shared, var = _interval_rows(trace, t)
        d = kernels.mab_decide_arrivals(es["mab"], shared, self.mab_hp[0])
        return kernels.select_variant(shared, var, d), es

    def place(self, es, state, cl, trace, t, interval_s):
        req = kernels.bestfit_requests(state, cl)
        if self.daso_cfg is not None:
            feat = kernels.state_features_k(state, cl, trace["lat_prev"][t],
                                            interval_s)
            req = kernels.daso_requests(self.daso_cfg, es["theta"], state,
                                        feat, req)
        return req, es, None

    def feedback(self, es, state, fin, util, aux, t, interval_s):
        _, phi, gamma, k_rbed = self.mab_hp
        es = dict(es)
        es["mab"] = kernels.mab_feedback(es["mab"], state, fin, phi, gamma,
                                         k_rbed)
        return es

    def outputs(self, es):
        mab = es["mab"]
        return {"mab_eps": mab.eps, "mab_rho": mab.rho, "mab_t": mab.t}

    def summarize(self, out, s):
        return _mab_scalars(out, s)

    def telemetry_cols(self):
        return MAB_TELEMETRY_COLS

    def telemetry_row(self, es):
        return _mab_telemetry_row(es["mab"])


@dataclasses.dataclass(frozen=True)
class MABTrainEngine:
    """The full §6.3 training loop in the carry: ε-greedy MAB decisions
    (eq. 6, prefix-stable fold-in keys), Algorithm-1 feedback, and —
    with a ``daso_cfg`` — online DASO finetuning (cold-start-gated
    ascent of the CARRIED theta, replay-window appends, weighted train
    epochs).  ``es = {"mab", "theta", "opt", "win", "key"}``; only the
    per-trace PRNG key is batched per grid cell."""

    mab_hp: Tuple[float, float, float, float]
    train_hp: Tuple[float, float, int, int, int]
    daso_cfg: Optional[DASOConfig] = None
    name: str = "mab-train"

    def batch_axes(self):
        return {"mab": None, "theta": None, "opt": None, "win": None,
                "key": 0}

    def decide(self, es, trace, t):
        shared, var = _interval_rows(trace, t)
        key_t = jax.random.fold_in(es["key"], t)
        d = kernels.mab_decide_arrivals_train(es["mab"], shared, key_t)
        return kernels.select_variant(shared, var, d), es

    def place(self, es, state, cl, trace, t, interval_s):
        req = kernels.bestfit_requests(state, cl)
        aux = None
        if self.daso_cfg is not None:
            feat = kernels.state_features_k(state, cl, trace["lat_prev"][t],
                                            interval_s)
            # cold-start gate reads the PRE-interval record count — place
            # happens before this interval's (x, y) append, and exactly
            # one record lands per interval, so the count equals the
            # (unbatched) interval index: gating on t keeps lax.cond a
            # real branch under vmap and lets it skip the ascent during
            # cold start
            use_opt = t >= self.train_hp[3]
            req, aux = kernels.daso_requests_train(
                self.daso_cfg, es["theta"], state, feat, req, use_opt)
        return req, es, aux

    def feedback(self, es, state, fin, util, aux, t, interval_s):
        _, phi, gamma, k_rbed = self.mab_hp
        alpha, beta, train_steps, _, train_min = self.train_hp
        es = dict(es)
        es["mab"] = kernels.mab_feedback(es["mab"], state, fin, phi, gamma,
                                         k_rbed)
        if self.daso_cfg is not None:
            y = daso_mod.op_objective(
                state["resp"], state["sla"], state["acc"], fin, util,
                interval_s, alpha, beta)
            es["win"] = daso_mod.window_append(es["win"], aux, y)
            es["theta"], es["opt"] = daso_mod.finetune_window(
                self.daso_cfg, es["theta"], es["opt"], es["win"],
                train_steps, train_min)
        return es

    def outputs(self, es):
        mab = es["mab"]
        out = {"mab_eps": mab.eps, "mab_rho": mab.rho, "mab_t": mab.t}
        if self.daso_cfg is not None:
            out["daso_theta"] = es["theta"]
        return out

    def summarize(self, out, s):
        s = _mab_scalars(out, s)
        if "daso_theta" in out:
            s["daso_theta"] = out["daso_theta"]
        return s

    def telemetry_cols(self):
        if self.daso_cfg is None:
            return MAB_TELEMETRY_COLS
        return MAB_TELEMETRY_COLS + ("daso_win_fill", "daso_last_loss")

    def telemetry_row(self, es):
        row = _mab_telemetry_row(es["mab"])
        if self.daso_cfg is None:
            return row
        f8 = jnp.float64
        loss = daso_mod.window_loss(self.daso_cfg, es["theta"], es["win"])
        return jnp.concatenate(
            [row, jnp.stack([es["win"]["count"].astype(f8),
                             loss.astype(f8)])])


@dataclasses.dataclass(frozen=True)
class GillisEngine:
    """Gillis baseline in the carry: contextual ε-greedy Q-learning
    between the layer split (arm 0) and model compression (arm 1), with
    multiplicative ε-decay per interval and sequential per-leaving-task
    TD(0) updates — the array form of ``splitplace.GillisDecider``
    against dual traces compiled with ``variants=(LAYER, COMPRESSED)``.
    ``gillis_hp = (eps0, lr, decay)``; eps0 seeds ``es["eps"]`` (the
    driver owns state construction).  Placement is plain BestFit.
    ``es = {"Q", "eps", "key", "layer_ref"}``."""

    gillis_hp: Tuple[float, float, float]
    name: str = "gillis"

    def batch_axes(self):
        return {"Q": None, "eps": None, "key": 0, "layer_ref": None}

    def decide(self, es, trace, t):
        shared, var = _interval_rows(trace, t)
        key_t = jax.random.fold_in(es["key"], t)
        arms = kernels.gillis_decide_arrivals(es["Q"], es["eps"], shared,
                                              key_t, es["layer_ref"])
        arr = kernels.select_variant(shared, var, arms,
                                     arm_decisions=GILLIS_VARIANTS)
        # ε decays once per scheduling interval, after the interval's
        # decisions (GillisDecider.decide's trailing `eps *= decay`)
        es = dict(es)
        es["eps"] = es["eps"] * self.gillis_hp[2]
        return arr, es

    def place(self, es, state, cl, trace, t, interval_s):
        return kernels.bestfit_requests(state, cl), es, None

    def feedback(self, es, state, fin, util, aux, t, interval_s):
        es = dict(es)
        es["Q"] = kernels.gillis_feedback(es["Q"], state, fin,
                                          es["layer_ref"],
                                          self.gillis_hp[1])
        return es

    def outputs(self, es):
        return {"gillis_eps": es["eps"], "gillis_q": es["Q"]}

    def summarize(self, out, s):
        s["gillis_eps"] = float(out["gillis_eps"])
        s["gillis_q"] = np.asarray(out["gillis_q"], np.float64)
        return s

    def telemetry_cols(self):
        return ("gillis_eps", "gillis_q_min", "gillis_q_max")

    def telemetry_row(self, es):
        f8 = jnp.float64
        return jnp.stack([es["eps"].astype(f8),
                          jnp.min(es["Q"]).astype(f8),
                          jnp.max(es["Q"]).astype(f8)])
