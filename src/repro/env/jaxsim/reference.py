"""Replay a compiled trace through the host ``EdgeSim`` — the parity
reference for the jitted backend.

The compiled trace carries pre-realized fragments and pre-sampled
accuracies, so the replay swaps the simulator's workload generator for a
scripted source that deals the identical tasks interval by interval.
Mobility needs no scripting: ``EdgeSim`` seeds its own ``MobilityModel``
with ``seed + 1`` exactly as ``compile_trace`` did, so the bandwidth
multipliers line up by construction.

``tests/test_jaxsim_parity.py`` pins ``run_trace_arrays`` ≈ this replay
(allclose on summary metrics) — the relaxed successor of the SoA↔legacy
bit-exactness contract.
"""
from __future__ import annotations

from typing import Optional

from repro.env.cluster import Cluster
from repro.env.jaxsim.arrays import TraceArrays
from repro.env.metrics import MetricsAccumulator
from repro.env.simulator import EdgeSim
from repro.env.workload import Fragment, Task


class _ScriptedSource:
    """Stands in for ``WorkloadGenerator``: deals the compiled trace's
    tasks per interval and replays its pre-sampled accuracies."""

    def __init__(self, trace: TraceArrays):
        self._acc = {}
        self._queues = []
        for t in range(trace.n_intervals):
            tasks = []
            for a in range(trace.max_arrivals):
                if not trace.arr_valid[t, a]:
                    continue
                tid = int(trace.arr_id[t, a])
                task = Task(id=tid, app=int(trace.arr_app[t, a]),
                            batch=int(trace.arr_batch[t, a]),
                            sla_s=float(trace.arr_sla[t, a]),
                            arrival_s=float(trace.arr_arrival_s[t, a]),
                            decision=int(trace.arr_decision[t, a]),
                            chain=bool(trace.arr_chain[t, a]))
                for i in range(int(trace.arr_nfrag[t, a])):
                    task.fragments.append(Fragment(
                        tid, i, float(trace.frag_instr[t, a, i]),
                        float(trace.frag_ram[t, a, i]),
                        float(trace.frag_out[t, a, i])))
                self._acc[tid] = float(trace.arr_acc[t, a])
                tasks.append(task)
            self._queues.append(tasks)
        self._t = 0

    def arrivals(self, now_s: float):
        if self._t >= len(self._queues):
            return []
        tasks = self._queues[self._t]
        self._t += 1
        return tasks

    def accuracy_of(self, task) -> float:
        return self._acc[task.id]


def replay_trace_edgesim(trace: TraceArrays,
                         cluster: Optional[Cluster] = None,
                         placer=None) -> dict:
    """Drive ``EdgeSim`` + BestFit through the compiled trace; returns the
    same summary schema as ``driver.run_trace_arrays``."""
    from repro.core.splitplace import BestFitPlacer
    sim = EdgeSim(cluster=cluster, lam=trace.lam, seed=trace.seed,
                  interval_s=trace.interval_s, substeps=trace.substeps)
    sim.gen = _ScriptedSource(trace)
    placer = placer or BestFitPlacer()
    acc = MetricsAccumulator(interval_s=trace.interval_s)
    for _ in range(trace.n_intervals):
        tasks = sim.new_interval_tasks()
        sim.admit(tasks, [0] * len(tasks))   # decisions pre-realized
        sim.apply_placement(placer.place(sim))
        acc.update(sim.advance())
    out = acc.summary()
    out["dropped_tasks"] = 0
    return out
