"""Replay a compiled trace through the host ``EdgeSim`` — the parity
reference for the jitted backend.

The compiled trace carries pre-realized fragments and pre-sampled
accuracies, so the replay swaps the simulator's workload generator for a
scripted source that deals the identical tasks interval by interval.
Mobility needs no scripting: ``EdgeSim`` seeds its own ``MobilityModel``
with ``seed + 1`` exactly as ``compile_trace`` did, so the bandwidth
multipliers line up by construction.

``tests/test_jaxsim_parity.py`` pins ``run_trace_arrays`` ≈ this replay
(allclose on summary metrics) — the relaxed successor of the SoA↔legacy
bit-exactness contract.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.env.cluster import Cluster
from repro.env.jaxsim.arrays import TraceArrays
from repro.env.metrics import TELEMETRY_COLS, MetricsAccumulator
from repro.env.simulator import EdgeSim
from repro.env.workload import Fragment, Task


def _attach_telemetry(out, acc, eng_cols=(), eng_rows=None):
    """Host-side twin of the driver's interval-mode summary extras:
    EXACT percentiles (the host keeps full sample lists, so the binning
    error bound is 0), plus the per-interval series — base
    ``TELEMETRY_COLS`` rows from the accumulator with the engine's
    learning-signal columns appended."""
    out.update(acc.percentiles())
    out["percentile_err_s"] = 0.0
    series = acc.telemetry_series()
    if eng_cols:
        series = np.concatenate(
            [series, np.asarray(eng_rows, np.float64).reshape(
                series.shape[0], len(eng_cols))], axis=1)
    out["telemetry"] = {"cols": list(TELEMETRY_COLS) + list(eng_cols),
                        "series": series}
    return out


class _ScriptedSource:
    """Stands in for ``WorkloadGenerator``: deals the compiled trace's
    tasks per interval and replays its pre-sampled accuracies."""

    def __init__(self, trace: TraceArrays):
        self._acc = {}
        self._queues = []
        for t in range(trace.n_intervals):
            tasks = []
            for a in range(trace.max_arrivals):
                if not trace.arr_valid[t, a]:
                    continue
                tid = int(trace.arr_id[t, a])
                task = Task(id=tid, app=int(trace.arr_app[t, a]),
                            batch=int(trace.arr_batch[t, a]),
                            sla_s=float(trace.arr_sla[t, a]),
                            arrival_s=float(trace.arr_arrival_s[t, a]),
                            decision=int(trace.arr_decision[t, a]),
                            chain=bool(trace.arr_chain[t, a]))
                for i in range(int(trace.arr_nfrag[t, a])):
                    task.fragments.append(Fragment(
                        tid, i, float(trace.frag_instr[t, a, i]),
                        float(trace.frag_ram[t, a, i]),
                        float(trace.frag_out[t, a, i])))
                self._acc[tid] = float(trace.arr_acc[t, a])
                tasks.append(task)
            self._queues.append(tasks)
        self._t = 0

    def arrivals(self, now_s: float):
        if self._t >= len(self._queues):
            return []
        tasks = self._queues[self._t]
        self._t += 1
        return tasks

    def accuracy_of(self, task) -> float:
        return self._acc[task.id]


def replay_trace_edgesim(trace: TraceArrays,
                         cluster: Optional[Cluster] = None,
                         placer=None, telemetry: str = "summary") -> dict:
    """Drive ``EdgeSim`` + BestFit through the compiled trace; returns the
    same summary schema as ``driver.run_trace_arrays``."""
    from repro.core.splitplace import BestFitPlacer
    tel = telemetry == "interval"
    sim = EdgeSim(cluster=cluster, lam=trace.lam, seed=trace.seed,
                  interval_s=trace.interval_s, substeps=trace.substeps)
    sim.gen = _ScriptedSource(trace)
    placer = placer or BestFitPlacer()
    acc = MetricsAccumulator(interval_s=trace.interval_s, telemetry=tel)
    for _ in range(trace.n_intervals):
        tasks = sim.new_interval_tasks()
        sim.admit(tasks, [0] * len(tasks))   # decisions pre-realized
        sim.apply_placement(placer.place(sim))
        acc.update(sim.advance())
    out = acc.summary()
    out["dropped_tasks"] = 0
    if tel:
        _attach_telemetry(out, acc)
    return out


# ---------------------------------------------- learned-policy reference
#
# The in-kernel learned policies (online MAB decider, array-form DASO
# placer) are pinned against the same host simulator: the replay below
# drives ``EdgeSim`` through a *dual* compiled trace, taking the split
# decisions / placements with the identical shared pure functions
# (``repro.core.mab`` masked feedback, ``repro.core.daso`` surrogate
# ascent) in the identical order, so the two backends see the same
# decision/placement trajectory and the metric contract stays
# allclose(rtol=1e-4).


class _AccuracyMap:
    """Minimal ``WorkloadGenerator`` stand-in for a learned replay: only
    ``accuracy_of`` is consulted (tasks are constructed pre-realized)."""

    def __init__(self):
        self._acc = {}

    def accuracy_of(self, task) -> float:
        return self._acc[task.id]


def _tasks_of_interval(trace, t, decisions, acc_map):
    """Materialize interval ``t``'s arrivals under the given per-row
    split *arm* indices (the V axis of the dual trace arrays); each
    task's recorded decision code comes from ``trace.variants`` —
    (LAYER, SEMANTIC) for MAB traces, (LAYER, COMPRESSED) for Gillis."""
    variants = getattr(trace, "variants", (0, 1))
    tasks = []
    rows = np.nonzero(trace.arr_valid[t])[0]
    for a, d in zip(rows, decisions):
        tid = int(trace.arr_id[t, a])
        task = Task(id=tid, app=int(trace.arr_app[t, a]),
                    batch=int(trace.arr_batch[t, a]),
                    sla_s=float(trace.arr_sla[t, a]),
                    arrival_s=float(trace.arr_arrival_s[t, a]),
                    decision=int(variants[d]),
                    chain=bool(trace.var_chain[t, a, d]))
        for i in range(int(trace.var_nfrag[t, a, d])):
            task.fragments.append(Fragment(
                tid, i, float(trace.var_instr[t, a, d, i]),
                float(trace.var_ram[t, a, d, i]),
                float(trace.var_out[t, a, d, i])))
        acc_map._acc[tid] = float(trace.var_acc[t, a, d])
        tasks.append(task)
    return tasks


def _daso_assignment(sim, cfg, theta, warm):
    """Host mirror of ``kernels.daso_requests``: same container
    enumeration (admission order, ``max_containers`` head), same
    warm-start logits, same float64 ``optimize_placement`` — so both
    backends feed the feasibility repair identical requests."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core import daso as daso_mod

    conts = sim.containers()
    C = cfg.max_containers
    head = conts[:C]
    feat = sim.state_features()
    warm_w = np.zeros(C, np.int32)
    rowvalid = np.zeros(C, bool)
    dec = np.zeros(C, np.int32)
    for i, (task, f) in enumerate(head):
        rowvalid[i] = True
        dec[i] = min(task.decision, 1)
        w = f.worker if f.worker >= 0 else warm[(task.id, f.idx)]
        warm_w[i] = w
    with enable_x64():
        logits = daso_mod.warm_start_logits(cfg, jnp.asarray(warm_w),
                                            jnp.asarray(rowvalid))
        p_opt, _, _ = daso_mod.optimize_placement(
            cfg, theta, jnp.asarray(feat), logits, jnp.asarray(dec),
            jnp.asarray(rowvalid, jnp.float64))
        assign = np.asarray(jnp.argmax(p_opt, axis=-1))
    out = dict(warm)
    for i, (task, f) in enumerate(head):
        out[(task.id, f.idx)] = int(assign[i])
    return out


def _daso_rows_host(sim, cfg, warm):
    """Host mirror of ``kernels._daso_rows``: the first ``max_containers``
    live fragments in ``EdgeSim.containers`` (admission) order with their
    warm-start workers and clipped decisions."""
    conts = sim.containers()
    C = cfg.max_containers
    head = conts[:C]
    warm_w = np.zeros(C, np.int32)
    rowvalid = np.zeros(C, bool)
    dec = np.zeros(C, np.int32)
    for i, (task, f) in enumerate(head):
        rowvalid[i] = True
        dec[i] = min(task.decision, 1)
        w = f.worker if f.worker >= 0 else warm[(task.id, f.idx)]
        warm_w[i] = w
    return head, warm_w, rowvalid, dec


def replay_trace_edgesim_trained(trace, mab_state, daso_theta=None,
                                 daso_cfg=None, daso_opt_state=None,
                                 cluster: Optional[Cluster] = None,
                                 mab_hp=None, train_hp=None,
                                 telemetry: str = "summary") -> dict:
    """Drive ``EdgeSim`` through a dual compiled trace under the FULL
    training loop — ε-greedy MAB decisions (eq. 6) from the shared
    fold-in key choreography, Algorithm-1 feedback with RBED ε-decay,
    and (when ``daso_cfg`` is given) online DASO finetuning: per-interval
    (packed placement features, O^P) replay-window appends and
    ``train_epoch_weighted`` steps through the identical shared pure
    functions.  The parity oracle for ``driver.run_*_arrays_trained``;
    returns the same summary schema including the final MAB scalars and
    (DASO runs) the finetuned ``theta`` under ``"daso_theta"``."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core import daso as daso_mod
    from repro.core import mab as mab_mod
    from repro.core.splitplace import BestFitPlacer
    from repro.env.jaxsim.driver import MAB_HP, TRAIN_HP, trace_train_key
    from repro.optim.optimizers import adamw_init

    _, phi, gamma, k_rbed = mab_hp or MAB_HP
    alpha, beta, train_steps, place_min, train_min = train_hp or TRAIN_HP
    tel = telemetry == "interval"
    eng_rows = []
    sim = EdgeSim(cluster=cluster, lam=trace.lam, seed=trace.seed,
                  interval_s=trace.interval_s, substeps=trace.substeps)
    acc_map = _AccuracyMap()
    sim.gen = acc_map
    bestfit = BestFitPlacer()
    acc = MetricsAccumulator(interval_s=trace.interval_s, telemetry=tel)
    with enable_x64():
        mab = jax.tree_util.tree_map(jnp.asarray, mab_state)
        theta = jax.tree_util.tree_map(jnp.asarray, daso_theta) \
            if daso_theta is not None else None
        if daso_cfg is not None:
            opt = jax.tree_util.tree_map(
                jnp.asarray, daso_opt_state if daso_opt_state is not None
                else adamw_init(theta))
            win = daso_mod.window_init(daso_cfg)
        key = trace_train_key(trace.seed)
    for t in range(trace.n_intervals):
        rows = np.nonzero(trace.arr_valid[t])[0]
        sla_n = (trace.arr_sla[t, rows] * 40000.0
                 / np.maximum(trace.arr_batch[t, rows].astype(np.float64),
                              1.0)).astype(np.float32)
        with enable_x64():
            key_t = jax.random.fold_in(key, t)
            d, _ = mab_mod.decide_train_rows(
                mab, key_t, jnp.asarray(sla_n),
                jnp.asarray(trace.arr_app[t, rows]))
        decisions = np.asarray(d)
        tasks = _tasks_of_interval(trace, t, decisions, acc_map)
        sim.admit(tasks, decisions)
        warm = bestfit.place(sim)
        if daso_cfg is not None:
            head, warm_w, rowvalid, dec = _daso_rows_host(sim, daso_cfg,
                                                          warm)
            feat = sim.state_features()
            with enable_x64():
                logits = daso_mod.warm_start_logits(
                    daso_cfg, jnp.asarray(warm_w), jnp.asarray(rowvalid))
                mask = jnp.asarray(rowvalid, jnp.float64)
                # cold-start gate: warm logits verbatim until place_min
                # records exist.  One record lands per interval, so the
                # pre-append count equals t — the same interval-indexed
                # gate the kernel's lax.cond branches on, skipping the
                # ascent entirely during cold start on both backends
                if t >= place_min:
                    p_used, _, _ = daso_mod.optimize_placement(
                        daso_cfg, theta, jnp.asarray(feat), logits,
                        jnp.asarray(dec), mask)
                else:
                    p_used = logits
                assign = np.asarray(jnp.argmax(p_used, axis=-1))
                x = daso_mod.pack_input(daso_cfg, jnp.asarray(feat),
                                        p_used, jnp.asarray(dec), mask)
            out_asg = dict(warm)
            for i, (task, f) in enumerate(head):
                out_asg[(task.id, f.idx)] = int(assign[i])
            warm = out_asg
        sim.apply_placement(warm)
        stats = sim.advance()
        fin = sorted(stats.finished, key=lambda task: task.id)
        with enable_x64():
            batch = np.maximum(np.array([task.batch for task in fin],
                                        np.float64), 1.0)
            mab = mab_mod.end_of_interval_masked(
                mab,
                jnp.asarray(np.array([task.app for task in fin], np.int32)),
                jnp.asarray((np.array([task.sla_s for task in fin])
                             * 40000.0 / batch).astype(np.float32)),
                jnp.asarray((np.array([task.response_s for task in fin])
                             * 40000.0 / batch).astype(np.float32)),
                jnp.asarray(np.array([task.accuracy for task in fin],
                                     np.float32)),
                jnp.asarray(np.array([min(task.decision, 1) for task in fin],
                                     np.int32)),
                jnp.ones((len(fin),), bool), phi, gamma, k_rbed)
            if daso_cfg is not None:
                y = daso_mod.op_objective(
                    jnp.asarray(np.array([task.response_s for task in fin],
                                         np.float64)),
                    jnp.asarray(np.array([task.sla_s for task in fin],
                                         np.float64)),
                    jnp.asarray(np.array([task.accuracy for task in fin],
                                         np.float64)),
                    jnp.ones((len(fin),), bool),
                    jnp.asarray(stats.cpu_util), trace.interval_s,
                    alpha, beta)
                win = daso_mod.window_append(win, x, y)
                theta, opt = daso_mod.finetune_window(daso_cfg, theta, opt,
                                                      win, train_steps,
                                                      train_min)
            if tel:
                # sampled at the same point as the kernel engine's
                # telemetry_row: end of feedback, post-finetune
                row = [float(mab.eps), float(mab.rho),
                       float(mab.N[:, 0].sum()), float(mab.N[:, 1].sum())]
                if daso_cfg is not None:
                    row += [float(win["count"]),
                            float(daso_mod.window_loss(daso_cfg, theta,
                                                       win))]
                eng_rows.append(row)
        acc.update(stats)
    out = acc.summary()
    out["dropped_tasks"] = 0
    out["mab_eps"] = float(mab.eps)
    out["mab_rho"] = float(mab.rho)
    out["mab_t"] = int(mab.t)
    if daso_cfg is not None:
        out["daso_theta"] = jax.tree_util.tree_map(np.asarray, theta)
    if tel:
        from repro.env.jaxsim.engines import MAB_TELEMETRY_COLS
        cols = MAB_TELEMETRY_COLS if daso_cfg is None else \
            MAB_TELEMETRY_COLS + ("daso_win_fill", "daso_last_loss")
        _attach_telemetry(out, acc, cols, eng_rows)
    return out


def replay_trace_edgesim_learned(trace, mab_state, daso_theta=None,
                                 daso_cfg=None,
                                 cluster: Optional[Cluster] = None,
                                 mab_hp=None,
                                 telemetry: str = "summary") -> dict:
    """Drive ``EdgeSim`` through a dual compiled trace under the learned
    policy (online UCB MAB decider; DASO placer when ``daso_cfg`` is
    given, BestFit otherwise) — the parity reference for
    ``driver.run_trace_arrays_learned``.  Returns the same summary
    schema, including the final MAB scalars."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core import mab as mab_mod
    from repro.core.splitplace import BestFitPlacer
    from repro.env.jaxsim.driver import MAB_HP

    ucb_c, phi, gamma, k_rbed = mab_hp or MAB_HP
    tel = telemetry == "interval"
    eng_rows = []
    sim = EdgeSim(cluster=cluster, lam=trace.lam, seed=trace.seed,
                  interval_s=trace.interval_s, substeps=trace.substeps)
    acc_map = _AccuracyMap()
    sim.gen = acc_map
    bestfit = BestFitPlacer()
    acc = MetricsAccumulator(interval_s=trace.interval_s, telemetry=tel)
    with enable_x64():
        mab = jax.tree_util.tree_map(jnp.asarray, mab_state)
        theta = jax.tree_util.tree_map(jnp.asarray, daso_theta) \
            if daso_theta is not None else None
    for t in range(trace.n_intervals):
        rows = np.nonzero(trace.arr_valid[t])[0]
        sla_n = (trace.arr_sla[t, rows] * 40000.0
                 / np.maximum(trace.arr_batch[t, rows].astype(np.float64),
                              1.0)).astype(np.float32)
        with enable_x64():
            d, _ = mab_mod.decide_ucb_batch(
                mab, jnp.asarray(sla_n),
                jnp.asarray(trace.arr_app[t, rows]), ucb_c)
        decisions = np.asarray(d)
        tasks = _tasks_of_interval(trace, t, decisions, acc_map)
        sim.admit(tasks, decisions)
        warm = bestfit.place(sim)
        if daso_cfg is not None:
            warm = _daso_assignment(sim, daso_cfg, theta, warm)
        sim.apply_placement(warm)
        stats = sim.advance()
        fin = sorted(stats.finished, key=lambda task: task.id)
        with enable_x64():
            batch = np.maximum(np.array([task.batch for task in fin],
                                        np.float64), 1.0)
            mab = mab_mod.end_of_interval_masked(
                mab,
                jnp.asarray(np.array([task.app for task in fin], np.int32)),
                jnp.asarray((np.array([task.sla_s for task in fin])
                             * 40000.0 / batch).astype(np.float32)),
                jnp.asarray((np.array([task.response_s for task in fin])
                             * 40000.0 / batch).astype(np.float32)),
                jnp.asarray(np.array([task.accuracy for task in fin],
                                     np.float32)),
                jnp.asarray(np.array([min(task.decision, 1) for task in fin],
                                     np.int32)),
                jnp.ones((len(fin),), bool), phi, gamma, k_rbed)
            if tel:
                eng_rows.append([float(mab.eps), float(mab.rho),
                                 float(mab.N[:, 0].sum()),
                                 float(mab.N[:, 1].sum())])
        acc.update(stats)
    out = acc.summary()
    out["dropped_tasks"] = 0
    out["mab_eps"] = float(mab.eps)
    out["mab_rho"] = float(mab.rho)
    out["mab_t"] = int(mab.t)
    if tel:
        from repro.env.jaxsim.engines import MAB_TELEMETRY_COLS
        _attach_telemetry(out, acc, MAB_TELEMETRY_COLS, eng_rows)
    return out


def replay_trace_edgesim_static_daso(trace, policy: str, daso_theta=None,
                                     daso_cfg=None,
                                     cluster: Optional[Cluster] = None,
                                     telemetry: str = "summary") -> dict:
    """Drive ``EdgeSim`` through a dual compiled trace under one of the
    static-decider Table-4 baseline arms — fixed ``layer+gobi`` /
    ``semantic+gobi`` splits with decision-blind surrogate placement, or
    ``random+daso`` uniform-random splits (the kernel engine's per-row
    fold-in bitstream, so both backends realize identical decisions)
    with decision-aware placement.  The parity oracle for
    ``driver.run_*_arrays_static_daso``; returns the plain §6.4 summary
    schema.

    Note the random arm pins the *in-kernel* decider (JAX PRNG), not the
    object-loop ``splitplace.RandomDecider`` (NumPy ``RandomState``) —
    same algorithm, different bitstreams."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.splitplace import BestFitPlacer
    from repro.env.jaxsim.driver import STATIC_DASO_ARMS, trace_train_key

    arm = STATIC_DASO_ARMS[policy]
    if arm >= 0:
        daso_cfg = daso_cfg._replace(decision_aware=False)
    tel = telemetry == "interval"
    sim = EdgeSim(cluster=cluster, lam=trace.lam, seed=trace.seed,
                  interval_s=trace.interval_s, substeps=trace.substeps)
    acc_map = _AccuracyMap()
    sim.gen = acc_map
    bestfit = BestFitPlacer()
    acc = MetricsAccumulator(interval_s=trace.interval_s, telemetry=tel)
    with enable_x64():
        theta = jax.tree_util.tree_map(jnp.asarray, daso_theta)
        key = trace_train_key(trace.seed)
    for t in range(trace.n_intervals):
        rows = np.nonzero(trace.arr_valid[t])[0]
        if arm < 0:
            with enable_x64():
                key_t = jax.random.fold_in(key, t)
                decisions = np.array(
                    [int(jax.random.bernoulli(jax.random.fold_in(key_t, r)))
                     for r in range(len(rows))], np.int32)
        else:
            decisions = np.full(len(rows), arm, np.int32)
        tasks = _tasks_of_interval(trace, t, decisions, acc_map)
        sim.admit(tasks, decisions)
        warm = bestfit.place(sim)
        warm = _daso_assignment(sim, daso_cfg, theta, warm)
        sim.apply_placement(warm)
        acc.update(sim.advance())
    out = acc.summary()
    out["dropped_tasks"] = 0
    if tel:
        _attach_telemetry(out, acc)
    return out


def replay_trace_edgesim_gillis(trace, gillis_state=None,
                                cluster: Optional[Cluster] = None,
                                gillis_hp=None, num_apps: int = 3,
                                telemetry: str = "summary") -> dict:
    """Drive ``EdgeSim`` through a (LAYER, COMPRESSED) dual compiled
    trace under the in-kernel Gillis baseline — contextual ε-greedy
    Q-learning decisions from the shared fold-in key choreography,
    per-interval ε-decay, and sequential per-leaving-task TD(0) updates
    through the identical shared pure functions (``mab.gillis_*``).  The
    parity oracle for ``driver.run_*_arrays_gillis``; returns the same
    summary schema including the final ``gillis_eps`` scalar and
    ``gillis_q`` table.

    Note this pins the *in-kernel* Gillis arm (JAX PRNG), not the
    object-loop ``splitplace.GillisDecider`` (NumPy ``RandomState``) —
    same algorithm, different bitstreams."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core import mab as mab_mod
    from repro.core.splitplace import BestFitPlacer
    from repro.env.jaxsim.driver import (GILLIS_HP, gillis_layer_ref,
                                         trace_train_key)
    from repro.env.workload import LAYER

    eps0, lr, decay = gillis_hp or GILLIS_HP
    tel = telemetry == "interval"
    eng_rows = []
    sim = EdgeSim(cluster=cluster, lam=trace.lam, seed=trace.seed,
                  interval_s=trace.interval_s, substeps=trace.substeps)
    acc_map = _AccuracyMap()
    sim.gen = acc_map
    bestfit = BestFitPlacer()
    acc = MetricsAccumulator(interval_s=trace.interval_s, telemetry=tel)
    with enable_x64():
        layer_ref = jnp.asarray(gillis_layer_ref(num_apps))
        if gillis_state is None:
            Q = mab_mod.gillis_init(num_apps)
            eps = jnp.asarray(eps0, jnp.float64)
        else:
            Q = jnp.asarray(np.asarray(gillis_state["Q"], np.float64))
            eps = jnp.asarray(np.float64(gillis_state["eps"]))
        key = trace_train_key(trace.seed)
    for t in range(trace.n_intervals):
        rows = np.nonzero(trace.arr_valid[t])[0]
        with enable_x64():
            key_t = jax.random.fold_in(key, t)
            arms, _ = mab_mod.gillis_decide_rows(
                Q, eps, key_t, jnp.asarray(trace.arr_sla[t, rows]),
                jnp.asarray(trace.arr_batch[t, rows].astype(np.float64)),
                jnp.asarray(trace.arr_app[t, rows]), layer_ref)
            eps = eps * decay
        arms = np.asarray(arms)
        tasks = _tasks_of_interval(trace, t, arms, acc_map)
        sim.admit(tasks, arms)
        sim.apply_placement(bestfit.place(sim))
        stats = sim.advance()
        fin = sorted(stats.finished, key=lambda task: task.id)
        with enable_x64():
            sla = jnp.asarray(np.array([task.sla_s for task in fin],
                                       np.float64))
            batch = jnp.asarray(np.array([task.batch for task in fin],
                                         np.float64))
            apps = jnp.asarray(np.array([task.app for task in fin],
                                        np.int32))
            buckets = mab_mod.gillis_bucket(sla, batch, apps, layer_ref)
            fin_arms = jnp.asarray(np.array(
                [0 if task.decision == LAYER else 1 for task in fin],
                np.int32))
            rewards = jnp.asarray(np.array(
                [((task.response_s <= task.sla_s) + task.accuracy) / 2.0
                 for task in fin], np.float64))
            Q = mab_mod.gillis_update_masked(
                Q, apps, buckets, fin_arms, rewards,
                jnp.ones((len(fin),), bool), lr)
            if tel:
                # eps already carries this interval's decay (it decays in
                # decide, before feedback — same point the kernel samples)
                eng_rows.append([float(eps), float(Q.min()),
                                 float(Q.max())])
        acc.update(stats)
    out = acc.summary()
    out["dropped_tasks"] = 0
    out["gillis_eps"] = float(eps)
    out["gillis_q"] = np.asarray(Q, np.float64)
    if tel:
        _attach_telemetry(out, acc,
                          ("gillis_eps", "gillis_q_min", "gillis_q_max"),
                          eng_rows)
    return out
