"""JAX-native edge simulator: whole (seed × λ) grids in one compiled call.

``repro.env.jaxsim`` is the accelerator-resident successor of the SoA
NumPy simulator (``repro.env.soa`` / ``repro.env.simulator``): the same
interval physics — MIPS sharing, layer-chain activation transfer under
mobility-modulated NIC bandwidth, RAM over-subscription swap slowdown,
and the eq. 13–16 metric accumulators — expressed as a jitted
``lax.fori_loop`` over substeps, so an entire experiment grid runs as a
single ``vmap``-over-traces XLA executable.

Fixed-capacity array layout
---------------------------
The growable object/SoA store becomes a *fixed-capacity slot store* so
every shape is compile-time static:

  * ``K = max_active`` task slots, each with ``F = max_frags`` fragment
    columns.  Per-fragment state is dense ``(K, F)`` (``instr``, ``ram``,
    ``out_bytes``, ``worker``, ``done``, ``transfer``); per-task state is
    ``(K,)`` (``chain``, ``stage``, ``placed``, ``alive``, ``task_done``,
    ``sla``, ``arrival_s``, ``wait_s``, ``seq``…).
  * Liveness is mask-based: a free slot has ``alive=False`` and all
    fragment columns ``done=True``; fragment columns beyond a task's
    ``nfrag`` are born ``done=True`` with ``worker=-1``, so every physics
    mask excludes padding with no special cases.
  * Admission scatters each interval's (padded, ``valid``-masked) arrival
    rows into free slots; slot *identity* is arbitrary but admission
    *order* is preserved in ``seq``, and the sequential greedy placement
    passes iterate in ``argsort(seq)`` order — the same order the host
    simulator's feasibility repair walks its active list.
  * Arrivals beyond free capacity are dropped and *counted*
    (``dropped_tasks`` in every summary); size ``max_active`` so it stays
    zero (``arrays.default_capacity`` never drops).

Workloads are compiled host-side (``arrays.compile_trace``) — Poisson
arrivals, split decisions from a *static* decider
(``policies.make_static_decider``), realized fragments, pre-sampled
accuracies, and mobility multipliers — then ``driver.run_grid_arrays``
runs the whole grid batched.  Equivalence vs the host ``EdgeSim`` is
``allclose`` on per-trace summary metrics (response times, energy, cost,
utilization-derived quantities) against ``reference.replay_trace_edgesim``,
relaxing the SoA↔legacy bit-exactness contract (reduction orders differ
between ``segment_sum`` and sequential ``bincount``).

Every policy runs through ONE interval program driven by a
**PolicyEngine** (``engines``): the carry is ``(slot state, metric
accumulators, engine_state)`` and engines supply the
``decide/place/feedback`` hooks — one runner cache, chunk dispatcher
and summary path for all of them.  Learned policies run **in-kernel**
(``policies.LEARNED_POLICIES``): the SplitPlace MAB decider threads its
``MABState`` through the interval carry — online UCB decisions realized
against dual-variant traces (``arrays.compile_trace_dual``),
per-interval reward feedback and RBED ε-decay
(``kernels.mab_feedback``) — and the array-form DASO stage
(``kernels.daso_requests``) gradient-ascends the pretrained placement
surrogate between the BestFit request and feasibility-repair stages
(``"mab+gobi"`` is the decision-blind GOBI ablation of the same
machinery).  ``mode="train"`` (``run_*_arrays_trained``) moves the full
§6.3 *training* loop in-kernel too: ε-greedy decisions (eq. 6) from a
fold-in key threaded through the carry, and online DASO finetuning —
each interval appends its (packed placement features, O^P) pair into a
carried fixed 64-row replay window and advances (theta, opt_state)
with ``daso.train_epoch_weighted`` epochs, so the surrogate the placer
ascends is the finetuned one.  The Gillis baseline
(``run_*_arrays_gillis``) carries its contextual ε-greedy Q-table over
(LAYER, COMPRESSED) dual traces with per-interval ε-decay and
sequential TD(0) updates.  The parity references are
``reference.replay_trace_edgesim_learned`` /
``replay_trace_edgesim_trained`` / ``replay_trace_edgesim_gillis``,
which drive ``EdgeSim`` with the identical shared pure functions; see
``docs/POLICIES.md``.
"""
from repro.env.jaxsim import engines
from repro.env.jaxsim.arrays import (ClusterArrays, DualTraceArrays,
                                     TraceArrays, chunk_tapes, compile_trace,
                                     compile_trace_dual, default_capacity,
                                     stack_traces)
from repro.env.jaxsim.driver import (GILLIS_HP, MAB_HP,
                                     STATIC_DASO_ARMS, TRAIN_HP,
                                     cache_stats, clear_cache,
                                     set_cache_limit,
                                     gillis_init_state, run_grid_arrays,
                                     run_grid_arrays_gillis,
                                     run_grid_arrays_learned,
                                     run_grid_arrays_static_daso,
                                     run_grid_arrays_trained,
                                     run_grid_engine, run_trace_arrays,
                                     run_trace_arrays_gillis,
                                     run_trace_arrays_learned,
                                     run_trace_arrays_static_daso,
                                     run_trace_arrays_trained,
                                     run_trace_engine, trace_train_key)
from repro.env.jaxsim.policies import (DASO_LEARNED_POLICIES,
                                       LEARNED_POLICIES,
                                       MAB_LEARNED_POLICIES,
                                       STATIC_POLICIES, host_policy,
                                       make_static_decider)
from repro.env.jaxsim.stream import (RollingMetrics, StreamFeeder,
                                     StreamRunner, make_stream_policy,
                                     replay_stream, serve)
from repro.env.jaxsim.reference import (replay_trace_edgesim,
                                        replay_trace_edgesim_gillis,
                                        replay_trace_edgesim_learned,
                                        replay_trace_edgesim_static_daso,
                                        replay_trace_edgesim_trained)

__all__ = [
    "ClusterArrays", "DualTraceArrays", "TraceArrays", "chunk_tapes",
    "compile_trace",
    "compile_trace_dual", "default_capacity", "stack_traces", "GILLIS_HP",
    "MAB_HP", "STATIC_DASO_ARMS", "TRAIN_HP", "cache_stats", "clear_cache",
    "set_cache_limit", "engines", "gillis_init_state",
    "RollingMetrics", "StreamFeeder", "StreamRunner", "make_stream_policy",
    "replay_stream", "serve",
    "run_grid_arrays", "run_grid_arrays_gillis", "run_grid_arrays_learned",
    "run_grid_arrays_static_daso", "run_grid_arrays_trained",
    "run_grid_engine", "run_trace_arrays",
    "run_trace_arrays_gillis", "run_trace_arrays_learned",
    "run_trace_arrays_static_daso", "run_trace_arrays_trained",
    "run_trace_engine", "trace_train_key",
    "DASO_LEARNED_POLICIES", "LEARNED_POLICIES", "MAB_LEARNED_POLICIES",
    "STATIC_POLICIES", "host_policy", "make_static_decider",
    "replay_trace_edgesim", "replay_trace_edgesim_gillis",
    "replay_trace_edgesim_learned", "replay_trace_edgesim_static_daso",
    "replay_trace_edgesim_trained",
]
