"""Observability: run ledgers, provenance stamps, profiler hooks.

``RunLedger`` traces the host side of a jitted run (compile/dispatch/
chunk/summarize spans, runner-cache counters, warnings, interval-series
snapshots) and exports JSONL for ``tools/obs_report.py``; the in-kernel
half of the subsystem is the ``telemetry="interval"`` knob on
``repro.env.jaxsim`` (see ``docs/ARCHITECTURE.md`` § Observability).
"""
from repro.obs.ledger import (RunLedger, get_ledger, load_ledger_lines,
                              provenance_stamp, use_ledger)

__all__ = ["RunLedger", "get_ledger", "load_ledger_lines",
           "provenance_stamp", "use_ledger"]
