"""Host-side run ledger: nested wall-clock spans, counters, warnings,
interval-series snapshots and a provenance stamp, exported as JSONL.

The jitted stack is a black box between ``runner(leaves, cld, es0)``
and the NumPy pull-back — this module makes the *host* half of a run
observable: where wall-clock went (compile vs dispatch vs summarize),
how the runner cache behaved (``driver.cache_stats()`` counters feed
``add_cache_stats``), and on which jax/device fleet the numbers were
measured (``provenance_stamp`` — the single shared helper behind the
benchmark artifact stamps in ``benchmarks/_provenance``).

One process-global ledger is always active (``get_ledger``); scoped
recording swaps it with ``use_ledger``.  Recording is cheap — a lock
plus a dict append per event — so the driver instruments every run
unconditionally and benchmarks stay honest.  ``tools/obs_report.py``
renders a dumped ledger into a text report (span tree, cache stats,
sparkline interval curves).
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager


def provenance_stamp(**knobs) -> dict:
    """The run-provenance stamp: jax version + device fleet + dispatch
    knobs.  Pass knobs as keyword overrides; unpassed knobs record the
    process-wide defaults (env var / no device mesh)."""
    import jax
    prov = {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": jax.devices()[0].device_kind,
        "cpu_count": os.cpu_count(),
        # the jitted simulator's dispatch knobs; None devices = the
        # host thread-chunk dispatcher (no device mesh)
        "substep_impl": os.environ.get("JAXSIM_SUBSTEP_IMPL", "xla"),
        "devices": None,
    }
    prov.update(knobs)
    return prov


class RunLedger:
    """Append-only trace of one run: spans (nested via a thread-local
    stack, or an explicit ``parent=`` id for worker threads), counters,
    warnings, named interval series, and an optional cache-stats
    snapshot.  ``dump`` writes one JSON object per line."""

    def __init__(self, name: str = "run"):
        self.name = name
        self.created_s = time.time()
        self.provenance = None
        self.cache_stats = None
        self.events = []
        self.counters = {}
        self.series = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._next_id = 0

    # ------------------------------------------------------------ spans

    def _stack(self):
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def current_span(self):
        """Id of the innermost open span on THIS thread (None at root) —
        hand it to worker threads as their ``span(parent=...)``."""
        st = self._stack()
        return st[-1] if st else None

    @contextmanager
    def span(self, name: str, parent=None, **attrs):
        """Record a wall-clock span.  Nesting comes from the per-thread
        span stack; ``parent`` overrides it (how thread-pool chunk spans
        attach under the dispatch span that forked them)."""
        with self._lock:
            sid = self._next_id
            self._next_id += 1
        st = self._stack()
        pid = parent if parent is not None else (st[-1] if st else None)
        st.append(sid)
        t0 = time.perf_counter()
        try:
            yield sid
        finally:
            dur = time.perf_counter() - t0
            st.pop()
            ev = {"kind": "span", "id": sid, "parent": pid, "name": name,
                  "dur_s": dur}
            if attrs:
                ev["attrs"] = attrs
            with self._lock:
                self.events.append(ev)

    # ------------------------------------------- counters / warnings / data

    def count(self, name: str, n: int = 1):
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def warn(self, message: str, **attrs):
        ev = {"kind": "warning", "message": message}
        if attrs:
            ev["attrs"] = attrs
        with self._lock:
            self.events.append(ev)

    def warnings(self):
        with self._lock:
            return [e for e in self.events if e["kind"] == "warning"]

    def add_series(self, name: str, cols, data):
        """Attach a named (T, C) interval series (e.g. one trace's
        ``summary["telemetry"]`` payload) for the report's curves."""
        import numpy as np
        arr = np.asarray(data, np.float64)
        if arr.ndim != 2 or arr.shape[1] != len(tuple(cols)):
            raise ValueError(f"series {name!r}: data {arr.shape} does not "
                             f"match {len(tuple(cols))} cols")
        with self._lock:
            self.series.append({"name": name, "cols": list(cols),
                                "data": arr.tolist()})

    def add_cache_stats(self, stats: dict):
        """Snapshot ``driver.cache_stats()`` into the ledger (last call
        wins — take it after the runs you are reporting on)."""
        with self._lock:
            self.cache_stats = dict(stats)

    def stamp(self, **knobs) -> dict:
        """Fill the provenance block (lazy: imports jax)."""
        self.provenance = provenance_stamp(**knobs)
        return self.provenance

    # ---------------------------------------------------------- profiling

    @contextmanager
    def profile(self, trace_dir: str):
        """Opt-in ``jax.profiler`` trace around a block; the TensorBoard
        trace lands under ``trace_dir`` and the block is also recorded
        as a ledger span."""
        import jax
        os.makedirs(trace_dir, exist_ok=True)
        jax.profiler.start_trace(trace_dir)
        try:
            with self.span("profile", trace_dir=trace_dir):
                yield
        finally:
            jax.profiler.stop_trace()

    # ------------------------------------------------------------- export

    def to_lines(self):
        with self._lock:
            lines = [{"kind": "meta", "name": self.name,
                      "created_s": self.created_s,
                      "provenance": self.provenance}]
            lines += list(self.events)
            lines.append({"kind": "counters",
                          "counters": dict(self.counters)})
            if self.cache_stats is not None:
                lines.append({"kind": "cache_stats", **self.cache_stats})
            lines += [{"kind": "series", **s} for s in self.series]
        return lines

    def dump(self, path: str) -> str:
        """Write the ledger as JSONL (one event per line)."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            for ln in self.to_lines():
                f.write(json.dumps(ln) + "\n")
        return path


def load_ledger_lines(path: str):
    """Parse a dumped JSONL ledger back into its event dicts."""
    with open(path) as f:
        return [json.loads(ln) for ln in f if ln.strip()]


_ACTIVE = RunLedger("default")


def get_ledger() -> RunLedger:
    """The currently-active ledger (a process-global default unless a
    ``use_ledger`` scope is open)."""
    return _ACTIVE


@contextmanager
def use_ledger(ledger: RunLedger):
    """Route driver/benchmark instrumentation into ``ledger`` for the
    scope's duration, then restore the previous one."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = ledger
    try:
        yield ledger
    finally:
        _ACTIVE = prev
