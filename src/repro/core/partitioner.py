"""Gillis-style latency-optimal layer partitioning (the baseline's core
algorithm, [32] §4).

Given per-layer costs (FLOPs + activation bytes forwarded between
consecutive layers) and a fleet of workers with speeds/bandwidths, find
the contiguous partition of layers into at most K fragments that
minimizes end-to-end pipeline latency:

    latency(partition) = Σ_f  [ work(f) / speed(w_f)  +  hop(f→f+1) ]

Solved exactly by dynamic programming over (layer-prefix, fragments-used)
with greedy worker assignment per fragment (fastest free worker first —
optimal for a chain because fragments execute sequentially, so the same
worker may serve multiple fragments; we model the paper's serverless
setting where each fragment gets a fresh function, i.e. workers are not
contended across fragments of one request).

Also provides `memory_feasible_partition`: the Gillis memory-optimal mode
(fragments must fit a per-worker RAM budget with the fewest fragments).

Used by the Gillis simulator baseline and by the serving plans to choose
pipeline-stage boundaries from real per-layer cost tables.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class LayerCost:
    flops: float           # forward FLOPs of this layer
    out_bytes: float       # activation bytes forwarded to the next layer
    param_bytes: float     # resident weight bytes


def model_layer_costs(cfg, seq: int, batch: int) -> List[LayerCost]:
    """Analytic per-layer cost table for any assigned architecture."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    act_bytes = batch * seq * d * 2.0
    out = []
    for kind in cfg.layer_kinds:
        p = cfg._block_params(kind, d, hd)
        flops = 2.0 * p * batch * seq
        if kind in ("attn", "local_attn", "xattn", "attn_moe"):
            w = cfg.sliding_window or seq
            flops += 4.0 * batch * seq * min(w, seq) * cfg.num_heads * hd
        out.append(LayerCost(flops, act_bytes, p * 2.0))
    return out


def pipeline_latency(costs: Sequence[LayerCost], cuts: Sequence[int],
                     speed_flops: float, hop_bw: float) -> float:
    """cuts = fragment boundaries [0, c1, ..., L]; single-speed fleet."""
    total = 0.0
    for a, b in zip(cuts[:-1], cuts[1:]):
        total += sum(c.flops for c in costs[a:b]) / speed_flops
        if b < len(costs):
            total += costs[b - 1].out_bytes / hop_bw
    return total


def optimal_partition(costs: Sequence[LayerCost], max_fragments: int,
                      speeds: Sequence[float], hop_bw: float,
                      exact: bool = False):
    """DP over (prefix, fragments): minimize Σ work/speed + hops.

    speeds are sorted descending and fragment f runs on speeds[f % len]
    (round-robin over the fastest workers, the Gillis serverless model).
    Returns (cuts, latency).
    """
    L = len(costs)
    K = min(max_fragments, L)
    speeds = sorted(speeds, reverse=True)
    pre = np.zeros(L + 1)
    for i, c in enumerate(costs):
        pre[i + 1] = pre[i] + c.flops
    INF = float("inf")
    # dp[k][i] = min latency of first i layers in k fragments
    dp = np.full((K + 1, L + 1), INF)
    back = np.zeros((K + 1, L + 1), int)
    dp[0][0] = 0.0
    for k in range(1, K + 1):
        spd = speeds[(k - 1) % len(speeds)]
        for i in range(1, L + 1):
            for j in range(k - 1, i):
                seg = (pre[i] - pre[j]) / spd
                hop = costs[i - 1].out_bytes / hop_bw if i < L else 0.0
                cand = dp[k - 1][j] + seg + hop
                if cand < dp[k][i]:
                    dp[k][i] = cand
                    back[k][i] = j
    if exact:
        best_k = min(max_fragments, L)
    else:
        best_k = int(np.argmin(dp[:, L]))
    cuts = [L]
    i, k = L, best_k
    while k > 0:
        i = int(back[k][i])
        cuts.append(i)
        k -= 1
    cuts.reverse()
    return cuts, float(dp[best_k][L])


def memory_feasible_partition(costs: Sequence[LayerCost],
                              ram_budget_bytes: float):
    """Fewest contiguous fragments with per-fragment weights under budget
    (Gillis memory-optimal serving mode).  Greedy is optimal here."""
    cuts = [0]
    acc = 0.0
    for i, c in enumerate(costs):
        if acc + c.param_bytes > ram_budget_bytes and acc > 0:
            cuts.append(i)
            acc = 0.0
        acc += c.param_bytes
        if c.param_bytes > ram_budget_bytes:
            raise ValueError(f"layer {i} alone exceeds the RAM budget")
    cuts.append(len(costs))
    return cuts
