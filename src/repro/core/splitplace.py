"""SplitPlace policy (Algorithm 1) + ablations/baselines + experiment runner.

Deciders (split strategy per task)  ×  Placers (container -> worker):

    MAB (ε-greedy train / UCB deploy)    DASO (decision-aware surrogate)
    Fixed LAYER / SEMANTIC               GOBI (decision-blind surrogate)
    Random                               BestFit heuristic
    Gillis-style contextual Q-learning (layer vs compressed)
    MC (always compressed)

SplitPlace = MAB + DASO.  The paper's ablations: M+G, S+G, L+G, R+D; its
baselines: Gillis, MC.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import daso as daso_mod
from repro.core import mab as mab_mod
from repro.core.policies import Decider, Placer, Policy  # noqa: F401 (re-export)
from repro.env.simulator import EdgeSim
from repro.env.workload import COMPRESSED, LAYER, SEMANTIC

NUM_APPS = 3


# ------------------------------------------------------------- deciders

class MABDecider:
    def __init__(self, seed=0, train=True, state=None, ucb_c=0.5,
                 phi=0.3, gamma=0.3, k=0.1):
        # phi=0.3 (paper grid-searched 0.9): our responses are heavier-tailed,
        # re-grid-searched on cumulative reward (see EXPERIMENTS.md)
        self.state = state if state is not None else mab_mod.init_state(NUM_APPS)
        self.train = train
        self.key = jax.random.PRNGKey(seed)
        self.ucb_c, self.phi, self.gamma, self.k = ucb_c, phi, gamma, k

    @staticmethod
    def _norm(t):
        # batch-normalized SLA (beyond-paper: the paper's R^a is per-app
        # only; normalizing by batch removes batch-induced context
        # misclassification — see EXPERIMENTS.md §Reproduction notes)
        return t.sla_s * 40000.0 / max(t.batch, 1)

    def decide(self, tasks):
        out = []
        for t in tasks:
            if self.train:
                self.key, k = jax.random.split(self.key)
                d, _ = mab_mod.decide_train(self.state, k,
                                            jnp.float32(self._norm(t)), t.app)
            else:
                d, _ = mab_mod.decide_ucb(self.state,
                                          jnp.float32(self._norm(t)),
                                          t.app, self.ucb_c)
            out.append(int(d))
        return out

    def feedback(self, finished):
        if not finished:
            self.state = self.state._replace(t=self.state.t + 1)
            return
        apps = jnp.array([t.app for t in finished], jnp.int32)
        sla = jnp.array([self._norm(t) for t in finished], jnp.float32)
        resp = jnp.array([t.response_s * 40000.0 / max(t.batch, 1)
                          for t in finished], jnp.float32)
        acc = jnp.array([t.accuracy for t in finished], jnp.float32)
        dec = jnp.array([min(t.decision, 1) for t in finished], jnp.int32)
        self.state = mab_mod.end_of_interval(self.state, apps, sla, resp, acc,
                                             dec, self.phi, self.gamma, self.k)

    def interval_reward(self, finished):
        if not finished:
            return 0.0
        r = np.array([t.response_s for t in finished])
        s = np.array([t.sla_s for t in finished])
        p = np.array([t.accuracy for t in finished])
        return float(np.mean(((r <= s) + p) / 2.0))


class FixedDecider:
    def __init__(self, decision):
        self.decision = decision

    def decide(self, tasks):
        return [self.decision] * len(tasks)

    def feedback(self, finished):
        pass


class RandomDecider:
    def __init__(self, seed=0):
        self.rng = np.random.RandomState(seed)

    def decide(self, tasks):
        return list(self.rng.randint(0, 2, len(tasks)))

    def feedback(self, finished):
        pass


class GillisDecider:
    """Contextual Q-learning between layer-split and model compression,
    the hybrid the Gillis baseline uses (§2.1); ε-greedy with decay."""

    def __init__(self, seed=0, eps=0.5, lr=0.3, decay=0.995):
        self.Q = np.zeros((NUM_APPS, 2, 2))   # (app, sla_bucket, arm)
        self.rng = np.random.RandomState(seed)
        self.eps, self.lr, self.decay = eps, lr, decay
        self.ref = np.array([1.0, 1.0, 1.0])

    def _ctx(self, t):
        from repro.env.workload import layer_ref_response_s
        ref = layer_ref_response_s(t.app) * t.batch / 40000.0 * 1.6
        return t.app, int(t.sla_s < ref)

    def decide(self, tasks):
        out = []
        for t in tasks:
            a, b = self._ctx(t)
            if self.rng.rand() < self.eps:
                arm = self.rng.randint(2)
            else:
                arm = int(np.argmax(self.Q[a, b]))
            out.append(LAYER if arm == 0 else COMPRESSED)
        self.eps *= self.decay
        return out

    def feedback(self, finished):
        for t in finished:
            a, b = self._ctx(t)
            arm = 0 if t.decision == LAYER else 1
            r = ((t.response_s <= t.sla_s) + t.accuracy) / 2.0
            self.Q[a, b, arm] += self.lr * (r - self.Q[a, b, arm])


# -------------------------------------------------------------- placers

class BestFitPlacer:
    """Greedy: keep existing placements; new fragments go to the worker
    maximizing a free-RAM / low-load score (no migration)."""

    def place(self, sim) -> Dict:
        n = sim.cluster.n
        ram_cap = sim.cluster.ram()
        if hasattr(sim, "fragment_store"):
            # vectorized census over the SoA store
            st = sim.fragment_store()
            F, T = st.n_fragments, st.n_tasks
            worker = st.worker[:F]
            live = ~st.done[:F]
            placedm = live & (worker >= 0)
            pw = worker[placedm]
            ram_used = np.bincount(pw, weights=st.ram_mb[:F][placedm],
                                   minlength=n)
            load = np.bincount(pw, minlength=n).astype(np.float64)
            new_rows = np.nonzero(live & (worker < 0))[0]
            tids = st.task_id[:T][st.task_of[new_rows]].tolist()
            idxs = st.frag_idx[new_rows].tolist()
            rams = st.ram_mb[new_rows].tolist()
            new = list(zip(tids, idxs, rams))
        else:
            # per-object census (legacy reference sim) — accumulation
            # order matches the bincount above, so outputs are identical
            ram_used = np.zeros(n)
            load = np.zeros(n)
            new = []
            for task, f in sim.containers():
                if f.worker >= 0:
                    ram_used[f.worker] += f.ram_mb
                    load[f.worker] += 1
                else:
                    new.append((task.id, f.idx, f.ram_mb))
        # already-placed fragments are left out of the assignment:
        # apply_placement defaults each fragment to its current worker
        out = {}
        if not new:
            return out
        ram_free = ram_cap - ram_used
        mips = sim.cluster.mips()
        static = 0.3 * mips / mips.max()
        # least-loaded first (runnable queue depth dominates response
        # time), prefer fast workers, require RAM feasibility; the score
        # vector is maintained incrementally — each greedy admit only
        # changes the chosen worker's entry.  Scalar state lives in Python
        # lists (fast in the sequential loop) with NumPy mirrors for the
        # vectorized feasibility mask + argmax.
        #
        # 1000-worker note (measured, bit-exact harness in PR 3): this
        # masked-argmax walk IS the fast form at edge-fleet sizes.  Four
        # exact alternatives — a top-k argpartition candidate window, a
        # lazy-deletion max-heap, feasibility-lazy masking, and a
        # per-task closed-form batch (a task's picks are the top-F of
        # per-worker arithmetic decay sequences, one lexsort) — all
        # benchmarked *slower* at n=1000–4000 (0.3–1.1×): a NumPy C scan
        # over 1000 float64 costs ~1µs, so per-pick Python/dispatch
        # overhead dominates and O(n)→O(k) scan savings never amortize.
        # At λ matched to fleet size the walk is ~4µs per new fragment;
        # `benchmarks/sim_throughput.py --quick` (soa_1000_workers)
        # tracks the end-to-end cost.
        score_np = -load + static + 0.1 * ram_free / ram_cap
        ram_free_l = ram_free.tolist()
        load_l = load.tolist()
        static_l = static.tolist()
        cap_l = ram_cap.tolist()
        buf = np.empty_like(score_np)
        cur_rmb = None
        for tid, idx, ram_mb in new:
            if ram_mb != cur_rmb:
                # feasibility-masked score buffer, rebuilt only when the
                # RAM demand changes (fragments of one task share it)
                np.copyto(buf, score_np)
                buf[ram_free < ram_mb] = -1e9
                cur_rmb = ram_mb
            w = int(buf.argmax())
            out[(tid, idx)] = w
            rf = ram_free_l[w] - ram_mb
            ram_free_l[w] = rf
            ram_free[w] = rf
            ld = load_l[w] + 1.0
            load_l[w] = ld
            sc = -ld + static_l[w] + 0.1 * rf / cap_l[w]
            score_np[w] = sc
            buf[w] = sc if rf >= ram_mb else -1e9
        return out

    def feedback(self, *a, **k):
        pass


class SurrogatePlacer:
    """DASO (decision-aware) or GOBI (decision-blind) placement: gradient
    ascent through an online-finetuned FCN surrogate of O^P (eqs. 10–12)."""

    def __init__(self, n_workers, decision_aware=True, seed=0,
                 max_containers=64, alpha=0.5, beta=0.5,
                 replay_cap=512, train_steps=4):
        self.cfg = daso_mod.DASOConfig(
            num_workers=n_workers, max_containers=max_containers,
            state_features=4, decision_aware=decision_aware)
        key = jax.random.PRNGKey(seed)
        self.theta, self.opt_state = daso_mod.make_trainer(self.cfg, key)
        self.alpha, self.beta = alpha, beta
        self.replay_x, self.replay_y = [], []
        self.replay_cap = replay_cap
        self.train_steps = train_steps
        self._last_x = None
        self.rng = np.random.RandomState(seed)
        self._fallback = BestFitPlacer()

    def place(self, sim: EdgeSim) -> Dict:
        conts = sim.containers()
        C = self.cfg.max_containers
        head, tail = conts[:C], conts[C:]
        state = jnp.asarray(sim.state_features(), jnp.float32)
        W = self.cfg.num_workers
        # warm start: existing placements + BestFit for new fragments
        # (the paper's eq. 12 iterates from P_{t-1})
        warm = self._fallback.place(sim)
        logits = np.asarray(self.rng.normal(0, 0.05, (C, W)), np.float32)
        decisions = np.zeros((C,), np.int32)
        mask = np.zeros((C,), np.float32)
        for i, (task, f) in enumerate(head):
            mask[i] = 1.0
            decisions[i] = min(task.decision, 1)
            w = f.worker if f.worker >= 0 else warm.get((task.id, f.idx), -1)
            if w >= 0:
                logits[i, w] = 2.0
        if len(self.replay_x) >= 32:
            # surrogate has enough trace data: gradient-ascend placement
            p_opt, score, iters = daso_mod.optimize_placement(
                self.cfg, self.theta, state, jnp.asarray(logits),
                jnp.asarray(decisions), jnp.asarray(mask))
        else:
            # cold start: keep the warm-start placement, still record data
            p_opt = jnp.asarray(logits)
        assign = daso_mod.placement_to_assignment(p_opt, jnp.asarray(mask))
        assign = np.asarray(assign)
        out = {}
        for i, (task, f) in enumerate(head):
            out[(task.id, f.idx)] = int(assign[i])
        if tail:
            # container overflow (> max_containers): fall back to BestFit
            # wholesale, as the seed did — greedy for unplaced fragments
            # and current workers for placed ones (BestFit now omits the
            # latter from its dict, so revert them explicitly)
            out.update(self._fallback.place(sim))
            for task, f in head:
                if f.worker >= 0:
                    out[(task.id, f.idx)] = f.worker
        self._last_x = np.asarray(daso_mod.pack_input(
            self.cfg, state, p_opt, jnp.asarray(decisions),
            jnp.asarray(mask)))
        return out

    def feedback(self, o_mab, stats, sim):
        """Record O^P = O^MAB − α·AEC − β·ART and finetune (eq. 11)."""
        if self._last_x is None:
            return
        aec = float(np.mean(stats.cpu_util))
        if stats.finished:
            art = float(np.mean([t.response_s for t in stats.finished])
                        / (6 * sim.interval_s))
        else:
            art = 0.0
        y = o_mab - self.alpha * aec - self.beta * min(art, 1.0)
        self.replay_x.append(self._last_x)
        self.replay_y.append(y)
        if len(self.replay_x) > self.replay_cap:
            self.replay_x.pop(0)
            self.replay_y.pop(0)
        if len(self.replay_x) >= 8:
            # fixed 64-row window, zero-weight padded: keeps train_epoch's
            # jit cache to one trace per config instead of one per replay
            # length (and lets concurrent experiment runs share it)
            win_x = self.replay_x[-64:]
            win_y = self.replay_y[-64:]
            k = len(win_x)
            xs_np = np.zeros((64,) + win_x[0].shape, np.float32)
            xs_np[:k] = np.stack(win_x)
            ys_np = np.zeros((64,), np.float32)
            ys_np[:k] = win_y
            w_np = np.zeros((64,), np.float32)
            w_np[:k] = 1.0
            xs, ys = jnp.asarray(xs_np), jnp.asarray(ys_np)
            w = jnp.asarray(w_np)
            for _ in range(self.train_steps):
                self.theta, self.opt_state, loss = \
                    daso_mod.train_epoch_weighted(
                        self.cfg, self.theta, self.opt_state, xs, ys, w)


# -------------------------------------------------------------- policies


def make_policy(name: str, n_workers: int, seed: int = 0,
                mab_state=None, train=False) -> Policy:
    mk_mab = lambda: MABDecider(seed=seed, train=train, state=mab_state)
    table = {
        "splitplace": lambda: Policy("MAB+DASO", mk_mab(),
                                     SurrogatePlacer(n_workers, True, seed)),
        "mab+gobi": lambda: Policy("MAB+GOBI", mk_mab(),
                                   SurrogatePlacer(n_workers, False, seed)),
        "semantic+gobi": lambda: Policy("Semantic+GOBI", FixedDecider(SEMANTIC),
                                        SurrogatePlacer(n_workers, False, seed)),
        "layer+gobi": lambda: Policy("Layer+GOBI", FixedDecider(LAYER),
                                     SurrogatePlacer(n_workers, False, seed)),
        "random+daso": lambda: Policy("Random+DASO", RandomDecider(seed),
                                      SurrogatePlacer(n_workers, True, seed)),
        "gillis": lambda: Policy("Gillis", GillisDecider(seed), BestFitPlacer()),
        "mc": lambda: Policy("MC", FixedDecider(COMPRESSED), BestFitPlacer()),
    }
    return table[name]()


def run_experiment(policy_name: str, n_intervals: int = 100, lam: float = 6.0,
                   seed: int = 0, mab_state=None, train: bool = False,
                   cluster=None, apps=None, interval_s: float = 300.0,
                   substeps: int = 30, policy=None) -> dict:
    """Run one execution trace; returns the §6.4 metric summary.
    Thin wrapper over ``repro.launch.experiments.run_trace`` (which owns
    the canonical interval loop; use ``run_grid`` there for batched
    (policy × seed × λ) studies).  Pass ``policy`` to continue a
    pre-trained policy object (used to pretrain the Gillis baseline's
    Q-learner, mirroring the MAB's pretraining phase)."""
    from repro.launch.experiments import run_trace
    return run_trace(policy_name, n_intervals=n_intervals, lam=lam,
                     seed=seed, mab_state=mab_state, train=train,
                     cluster=cluster, apps=apps, interval_s=interval_s,
                     substeps=substeps, policy=policy)


def pretrain_mab(n_intervals: int = 200, lam: float = 6.0, seed: int = 0,
                 substeps: int = 30):
    """Paper §6.3: 200 intervals of feedback-based ε-greedy training."""
    res = run_experiment("splitplace", n_intervals, lam, seed, train=True,
                         substeps=substeps)
    return res["mab_state"], res
