"""SplitPlace policy (Algorithm 1) + ablations/baselines + experiment runner.

Deciders (split strategy per task)  ×  Placers (container -> worker):

    MAB (ε-greedy train / UCB deploy)    DASO (decision-aware surrogate)
    Fixed LAYER / SEMANTIC               GOBI (decision-blind surrogate)
    Random                               BestFit heuristic
    Gillis-style contextual Q-learning (layer vs compressed)
    MC (always compressed)

SplitPlace = MAB + DASO.  The paper's ablations: M+G, S+G, L+G, R+D; its
baselines: Gillis, MC.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import daso as daso_mod
from repro.core import mab as mab_mod
from repro.env.metrics import MetricsAccumulator
from repro.env.simulator import EdgeSim
from repro.env.workload import COMPRESSED, LAYER, SEMANTIC

NUM_APPS = 3


# ------------------------------------------------------------- deciders

class MABDecider:
    def __init__(self, seed=0, train=True, state=None, ucb_c=0.5,
                 phi=0.3, gamma=0.3, k=0.1):
        # phi=0.3 (paper grid-searched 0.9): our responses are heavier-tailed,
        # re-grid-searched on cumulative reward (see EXPERIMENTS.md)
        self.state = state if state is not None else mab_mod.init_state(NUM_APPS)
        self.train = train
        self.key = jax.random.PRNGKey(seed)
        self.ucb_c, self.phi, self.gamma, self.k = ucb_c, phi, gamma, k

    @staticmethod
    def _norm(t):
        # batch-normalized SLA (beyond-paper: the paper's R^a is per-app
        # only; normalizing by batch removes batch-induced context
        # misclassification — see EXPERIMENTS.md §Reproduction notes)
        return t.sla_s * 40000.0 / max(t.batch, 1)

    def decide(self, tasks):
        out = []
        for t in tasks:
            if self.train:
                self.key, k = jax.random.split(self.key)
                d, _ = mab_mod.decide_train(self.state, k,
                                            jnp.float32(self._norm(t)), t.app)
            else:
                d, _ = mab_mod.decide_ucb(self.state,
                                          jnp.float32(self._norm(t)),
                                          t.app, self.ucb_c)
            out.append(int(d))
        return out

    def feedback(self, finished):
        if not finished:
            self.state = self.state._replace(t=self.state.t + 1)
            return
        apps = jnp.array([t.app for t in finished], jnp.int32)
        sla = jnp.array([self._norm(t) for t in finished], jnp.float32)
        resp = jnp.array([t.response_s * 40000.0 / max(t.batch, 1)
                          for t in finished], jnp.float32)
        acc = jnp.array([t.accuracy for t in finished], jnp.float32)
        dec = jnp.array([min(t.decision, 1) for t in finished], jnp.int32)
        self.state = mab_mod.end_of_interval(self.state, apps, sla, resp, acc,
                                             dec, self.phi, self.gamma, self.k)

    def interval_reward(self, finished):
        if not finished:
            return 0.0
        r = np.array([t.response_s for t in finished])
        s = np.array([t.sla_s for t in finished])
        p = np.array([t.accuracy for t in finished])
        return float(np.mean(((r <= s) + p) / 2.0))


class FixedDecider:
    def __init__(self, decision):
        self.decision = decision

    def decide(self, tasks):
        return [self.decision] * len(tasks)

    def feedback(self, finished):
        pass


class RandomDecider:
    def __init__(self, seed=0):
        self.rng = np.random.RandomState(seed)

    def decide(self, tasks):
        return list(self.rng.randint(0, 2, len(tasks)))

    def feedback(self, finished):
        pass


class GillisDecider:
    """Contextual Q-learning between layer-split and model compression,
    the hybrid the Gillis baseline uses (§2.1); ε-greedy with decay."""

    def __init__(self, seed=0, eps=0.5, lr=0.3, decay=0.995):
        self.Q = np.zeros((NUM_APPS, 2, 2))   # (app, sla_bucket, arm)
        self.rng = np.random.RandomState(seed)
        self.eps, self.lr, self.decay = eps, lr, decay
        self.ref = np.array([1.0, 1.0, 1.0])

    def _ctx(self, t):
        from repro.env.workload import layer_ref_response_s
        ref = layer_ref_response_s(t.app) * t.batch / 40000.0 * 1.6
        return t.app, int(t.sla_s < ref)

    def decide(self, tasks):
        out = []
        for t in tasks:
            a, b = self._ctx(t)
            if self.rng.rand() < self.eps:
                arm = self.rng.randint(2)
            else:
                arm = int(np.argmax(self.Q[a, b]))
            out.append(LAYER if arm == 0 else COMPRESSED)
        self.eps *= self.decay
        return out

    def feedback(self, finished):
        for t in finished:
            a, b = self._ctx(t)
            arm = 0 if t.decision == LAYER else 1
            r = ((t.response_s <= t.sla_s) + t.accuracy) / 2.0
            self.Q[a, b, arm] += self.lr * (r - self.Q[a, b, arm])


# -------------------------------------------------------------- placers

class BestFitPlacer:
    """Greedy: keep existing placements; new fragments go to the worker
    maximizing a free-RAM / low-load score (no migration)."""

    def place(self, sim: EdgeSim) -> Dict:
        ram_free = sim.cluster.ram().copy()
        load = np.zeros(sim.cluster.n)
        for task, f in sim.containers():
            if f.worker >= 0:
                ram_free[f.worker] -= f.ram_mb
                load[f.worker] += 1
        ram_cap = sim.cluster.ram()
        mips = sim.cluster.mips()
        out = {}
        for task, f in sim.containers():
            if f.worker >= 0:
                out[(task.id, f.idx)] = f.worker
                continue
            # least-loaded first (runnable queue depth dominates response
            # time), prefer fast workers, require RAM feasibility
            feasible = ram_free >= f.ram_mb
            score = (-load + 0.3 * mips / mips.max()
                     + 0.1 * ram_free / ram_cap)
            score = np.where(feasible, score, -1e9)
            w = int(np.argmax(score))
            out[(task.id, f.idx)] = w
            ram_free[w] -= f.ram_mb
            load[w] += 1
        return out

    def feedback(self, *a, **k):
        pass


class SurrogatePlacer:
    """DASO (decision-aware) or GOBI (decision-blind) placement: gradient
    ascent through an online-finetuned FCN surrogate of O^P (eqs. 10–12)."""

    def __init__(self, n_workers, decision_aware=True, seed=0,
                 max_containers=64, alpha=0.5, beta=0.5,
                 replay_cap=512, train_steps=4):
        self.cfg = daso_mod.DASOConfig(
            num_workers=n_workers, max_containers=max_containers,
            state_features=4, decision_aware=decision_aware)
        key = jax.random.PRNGKey(seed)
        self.theta, self.opt_state = daso_mod.make_trainer(self.cfg, key)
        self.alpha, self.beta = alpha, beta
        self.replay_x, self.replay_y = [], []
        self.replay_cap = replay_cap
        self.train_steps = train_steps
        self._last_x = None
        self.rng = np.random.RandomState(seed)
        self._fallback = BestFitPlacer()

    def place(self, sim: EdgeSim) -> Dict:
        conts = sim.containers()
        C = self.cfg.max_containers
        head, tail = conts[:C], conts[C:]
        state = jnp.asarray(sim.state_features(), jnp.float32)
        W = self.cfg.num_workers
        # warm start: existing placements + BestFit for new fragments
        # (the paper's eq. 12 iterates from P_{t-1})
        warm = self._fallback.place(sim)
        logits = np.asarray(self.rng.normal(0, 0.05, (C, W)), np.float32)
        decisions = np.zeros((C,), np.int32)
        mask = np.zeros((C,), np.float32)
        for i, (task, f) in enumerate(head):
            mask[i] = 1.0
            decisions[i] = min(task.decision, 1)
            w = f.worker if f.worker >= 0 else warm.get((task.id, f.idx), -1)
            if w >= 0:
                logits[i, w] = 2.0
        if len(self.replay_x) >= 32:
            # surrogate has enough trace data: gradient-ascend placement
            p_opt, score, iters = daso_mod.optimize_placement(
                self.cfg, self.theta, state, jnp.asarray(logits),
                jnp.asarray(decisions), jnp.asarray(mask))
        else:
            # cold start: keep the warm-start placement, still record data
            p_opt = jnp.asarray(logits)
        assign = daso_mod.placement_to_assignment(p_opt, jnp.asarray(mask))
        assign = np.asarray(assign)
        out = {}
        for i, (task, f) in enumerate(head):
            out[(task.id, f.idx)] = int(assign[i])
        if tail:
            out.update(self._fallback.place(sim))
        self._last_x = np.asarray(daso_mod.pack_input(
            self.cfg, state, p_opt, jnp.asarray(decisions),
            jnp.asarray(mask)))
        return out

    def feedback(self, o_mab, stats, sim):
        """Record O^P = O^MAB − α·AEC − β·ART and finetune (eq. 11)."""
        if self._last_x is None:
            return
        aec = float(np.mean(stats.cpu_util))
        if stats.finished:
            art = float(np.mean([t.response_s for t in stats.finished])
                        / (6 * sim.interval_s))
        else:
            art = 0.0
        y = o_mab - self.alpha * aec - self.beta * min(art, 1.0)
        self.replay_x.append(self._last_x)
        self.replay_y.append(y)
        if len(self.replay_x) > self.replay_cap:
            self.replay_x.pop(0)
            self.replay_y.pop(0)
        if len(self.replay_x) >= 8:
            xs = jnp.asarray(np.stack(self.replay_x[-64:]))
            ys = jnp.asarray(np.array(self.replay_y[-64:], np.float32))
            for _ in range(self.train_steps):
                self.theta, self.opt_state, loss = daso_mod.train_epoch(
                    self.cfg, self.theta, self.opt_state, xs, ys)


# -------------------------------------------------------------- policies

@dataclasses.dataclass
class Policy:
    name: str
    decider: object
    placer: object


def make_policy(name: str, n_workers: int, seed: int = 0,
                mab_state=None, train=False) -> Policy:
    mk_mab = lambda: MABDecider(seed=seed, train=train, state=mab_state)
    table = {
        "splitplace": lambda: Policy("MAB+DASO", mk_mab(),
                                     SurrogatePlacer(n_workers, True, seed)),
        "mab+gobi": lambda: Policy("MAB+GOBI", mk_mab(),
                                   SurrogatePlacer(n_workers, False, seed)),
        "semantic+gobi": lambda: Policy("Semantic+GOBI", FixedDecider(SEMANTIC),
                                        SurrogatePlacer(n_workers, False, seed)),
        "layer+gobi": lambda: Policy("Layer+GOBI", FixedDecider(LAYER),
                                     SurrogatePlacer(n_workers, False, seed)),
        "random+daso": lambda: Policy("Random+DASO", RandomDecider(seed),
                                      SurrogatePlacer(n_workers, True, seed)),
        "gillis": lambda: Policy("Gillis", GillisDecider(seed), BestFitPlacer()),
        "mc": lambda: Policy("MC", FixedDecider(COMPRESSED), BestFitPlacer()),
    }
    return table[name]()


def run_experiment(policy_name: str, n_intervals: int = 100, lam: float = 6.0,
                   seed: int = 0, mab_state=None, train: bool = False,
                   cluster=None, apps=None, interval_s: float = 300.0,
                   substeps: int = 30, policy=None) -> dict:
    """Run one execution trace; returns the §6.4 metric summary.
    Pass ``policy`` to continue a pre-trained policy object (used to
    pretrain the Gillis baseline's Q-learner, mirroring the MAB's
    pretraining phase)."""
    sim = EdgeSim(cluster=cluster, lam=lam, seed=seed, apps=apps,
                  interval_s=interval_s, substeps=substeps)
    policy = policy or make_policy(policy_name, sim.cluster.n, seed=seed,
                                   mab_state=mab_state, train=train)
    acc = MetricsAccumulator(interval_s=interval_s)
    for t in range(n_intervals):
        tasks = sim.new_interval_tasks()
        decisions = policy.decider.decide(tasks)
        sim.admit(tasks, decisions)
        assignment = policy.placer.place(sim)
        sim.apply_placement(assignment)
        stats = sim.advance()
        policy.decider.feedback(stats.finished)
        if isinstance(policy.placer, SurrogatePlacer):
            o_mab = (policy.decider.interval_reward(stats.finished)
                     if isinstance(policy.decider, MABDecider)
                     else MABDecider().interval_reward(stats.finished))
            policy.placer.feedback(o_mab, stats, sim)
        acc.update(stats)
    out = acc.summary()
    out["policy"] = policy.name
    out["policy_obj"] = policy
    if isinstance(policy.decider, MABDecider):
        out["mab_state"] = policy.decider.state
    return out


def pretrain_mab(n_intervals: int = 200, lam: float = 6.0, seed: int = 0,
                 substeps: int = 30):
    """Paper §6.3: 200 intervals of feedback-based ε-greedy training."""
    res = run_experiment("splitplace", n_intervals, lam, seed, train=True,
                         substeps=substeps)
    return res["mab_state"], res
