"""Decider / Placer contracts (the two halves of a SplitPlace policy).

The seed code passed deciders and placers around duck-typed; this module
pins the contract down so new strategies (and the batched experiment
runner in ``repro.launch.experiments``) can be written and type-checked
against an explicit surface.

A *decider* maps newly arrived tasks to split decisions (LAYER /
SEMANTIC / COMPRESSED, Algorithm 1 line 4); a *placer* maps the active
container set to workers (line 7).  Both observe the end-of-interval
outcome through ``feedback``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Protocol, Tuple, runtime_checkable


@runtime_checkable
class Decider(Protocol):
    def decide(self, tasks: List) -> List[int]:
        """Split decision per task (tasks are not yet realized)."""
        ...

    def feedback(self, finished: List) -> None:
        """Observe tasks that completed this interval (response/accuracy
        populated); learning deciders update their state here."""
        ...


@runtime_checkable
class Placer(Protocol):
    def place(self, sim) -> Dict[Tuple[int, int], int]:
        """Assignment ``(task_id, fragment_idx) -> worker`` for active
        containers.  Fragments omitted from the dict keep their current
        worker; the simulator feasibility-repairs the result against
        worker RAM (``EdgeSim.apply_placement``)."""
        ...

    def feedback(self, *args, **kwargs) -> None:
        """Observe the interval outcome (surrogate placers record the
        QoS target O^P here and finetune)."""
        ...


@dataclasses.dataclass
class Policy:
    """A named (decider, placer) pair — one Table 4 row."""
    name: str
    decider: Decider
    placer: Placer
