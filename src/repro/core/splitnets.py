"""Real layer-wise and semantic splitting of neural networks (Fig. 1/2).

The paper builds on two splitting schemes:

* **Layer-wise** [Gillis, 32]: partition a trained network's layers into
  sequential fragments.  Functionally EXACT — composing the fragments
  reproduces the monolithic output bit-for-bit (tested).  Cost: fragments
  execute sequentially, and intermediate activations travel between
  workers.

* **Semantic** [SplitNet, 16]: partition classes into groups; each branch
  is an independent sub-network (disjoint hidden features, no cross-branch
  weights) trained to score only its class group.  Branches run in
  parallel; the combiner concatenates class scores.  Accuracy drops
  (limited feature sharing), latency drops (parallel, each branch is
  1/G-th the width).

This module implements both for an MLP classifier family in JAX, providing
the paper's Fig. 2 trade-off from first principles rather than assuming it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ClassifierConfig:
    input_dim: int
    num_classes: int
    hidden: int = 256
    depth: int = 4            # number of hidden layers


def init_mlp(key, dims: Sequence[int]):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": jax.random.normal(k, (a, b)) * np.sqrt(2.0 / a),
             "b": jnp.zeros((b,))}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def mlp_apply(params, x):
    for i, p in enumerate(params):
        x = x @ p["w"] + p["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def classifier_dims(cfg: ClassifierConfig, width=None, out=None):
    h = width or cfg.hidden
    return [cfg.input_dim] + [h] * cfg.depth + [out or cfg.num_classes]


def train_classifier(key, cfg, x, y, dims=None, steps=300, lr=1e-2,
                     batch=256, class_subset=None):
    """Plain SGD-with-momentum training; returns params."""
    dims = dims or classifier_dims(cfg)
    params = init_mlp(key, dims)
    vel = jax.tree.map(jnp.zeros_like, params)
    n = x.shape[0]

    if class_subset is not None:
        sel = np.isin(y, class_subset)
        x, y = x[sel], y[sel]
        remap = {c: i for i, c in enumerate(class_subset)}
        y = np.vectorize(remap.get)(y)
        n = x.shape[0]
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    @jax.jit
    def step(params, vel, xb, yb):
        def loss(p):
            logits = mlp_apply(p, xb)
            lp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(lp, yb[:, None], 1).mean()
        l, g = jax.value_and_grad(loss)(params)
        vel = jax.tree.map(lambda v, g: 0.9 * v + g, vel, g)
        params = jax.tree.map(lambda p, v: p - lr * v, params, vel)
        return params, vel, l

    rng = np.random.RandomState(0)
    for i in range(steps):
        idx = rng.randint(0, n, batch)
        params, vel, l = step(params, vel, xj[idx], yj[idx])
    return params


def accuracy(params, x, y, apply=mlp_apply):
    pred = jnp.argmax(apply(params, jnp.asarray(x)), -1)
    return float((pred == jnp.asarray(y)).mean())


# ------------------------------------------------------------ layer split

def layer_split(params, num_fragments: int) -> List[list]:
    """Partition the layer list into ~equal sequential fragments."""
    L = len(params)
    num_fragments = min(num_fragments, L)
    bounds = np.linspace(0, L, num_fragments + 1).astype(int)
    return [params[a:b] for a, b in zip(bounds[:-1], bounds[1:]) if b > a]


def layer_split_apply(fragments, x):
    """Sequential (pipelined) execution of layer fragments."""
    h = x
    for i, frag in enumerate(fragments):
        last_fragment = i == len(fragments) - 1
        for j, p in enumerate(frag):
            h = h @ p["w"] + p["b"]
            is_output = last_fragment and j == len(frag) - 1
            if not is_output:
                h = jax.nn.relu(h)
    return h


def fragment_flops(fragments, batch=1):
    return [sum(2 * batch * p["w"].shape[0] * p["w"].shape[1] for p in f)
            for f in fragments]


# --------------------------------------------------------- semantic split

def class_groups(num_classes: int, num_branches: int):
    bounds = np.linspace(0, num_classes, num_branches + 1).astype(int)
    return [list(range(a, b)) for a, b in zip(bounds[:-1], bounds[1:])]


def feature_groups(input_dim: int, num_branches: int, coverage: float = 0.6):
    """Per-branch contiguous feature windows covering `coverage` of the
    input each (overlapping): SplitNet branches specialize on feature
    subsets; full disjointness is harsher than the published 2-7%% drop,
    60%% windows calibrate the penalty to Fig. 2's range."""
    if num_branches == 1:
        return [(0, input_dim)]
    w = max(1, int(input_dim * coverage))
    starts = np.linspace(0, input_dim - w, num_branches).astype(int)
    return [(int(a), int(a + w)) for a in starts]


def train_semantic_split(key, cfg: ClassifierConfig, x, y,
                         num_branches: int, steps=300):
    """Train disjoint per-class-group branches.

    Faithful to SplitNet [16]: each branch owns BOTH a class group and a
    disjoint slice of the input features (1/G width, no cross-branch
    weights or feature sharing) — this is where the semantic accuracy
    penalty physically comes from.
    """
    groups = class_groups(cfg.num_classes, num_branches)
    fgroups = feature_groups(cfg.input_dim, num_branches)
    keys = jax.random.split(key, num_branches)
    branches = []
    width = max(8, cfg.hidden // num_branches)
    for k, g, (lo, hi) in zip(keys, groups, fgroups):
        sub = dataclasses.replace(cfg, input_dim=hi - lo)
        dims = [hi - lo] + [width] * cfg.depth + [len(g)]
        branches.append(train_classifier(k, sub, x[:, lo:hi], y, dims=dims,
                                         steps=steps, class_subset=g))
    return branches, (groups, fgroups)


def semantic_split_apply(branches, groups, x):
    """Parallel branch execution + score concatenation (the combiner)."""
    cgroups, fgroups = groups
    outs = [mlp_apply(b, x[..., lo:hi])
            for b, (lo, hi) in zip(branches, fgroups)]
    # each branch scores only its classes; concatenate log-softmaxed scores
    parts = [jax.nn.log_softmax(o, -1) for o in outs]
    return jnp.concatenate(parts, axis=-1)
