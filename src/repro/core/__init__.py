"""SplitPlace core: the paper's contribution (MAB split decisions, DASO
placement, real split networks) + baselines."""
