"""DASO — Decision-Aware Surrogate Optimization placement module (§4.2).

An FCN surrogate f([S_t, P_t, D_t]; θ) predicts the QoS objective
O^P = O^MAB − α·AEC − β·ART (eq. 10).  It is trained with MSE (eq. 11,
AdamW) on execution traces, then the placement is found by gradient ascent
of the surrogate output w.r.t. a relaxed placement matrix (eq. 12), with
momentum/annealing as in GOBI, followed by feasibility repair.

The placement matrix is relaxed to logits (C_max × H); the simulator
consumes the row-argmax.  "Decision-aware" = the per-container split
decision one-hot is part of the surrogate input; the vanilla GOBI ablation
(M+G / S+G / L+G baselines) simply zeroes that slice.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import adamw_init, adamw_update


class DASOConfig(NamedTuple):
    num_workers: int
    max_containers: int
    state_features: int          # per-worker utilization features
    hidden: int = 128
    depth: int = 3
    lr_train: float = 1e-3
    lr_place: float = 0.1
    place_iters: int = 50
    momentum: float = 0.9
    tol: float = 1e-3
    decision_aware: bool = True


def feature_size(cfg: DASOConfig) -> int:
    # worker utilization state + placement logits + split-decision one-hots
    return (cfg.num_workers * cfg.state_features
            + cfg.max_containers * cfg.num_workers
            + cfg.max_containers * 2)


def init_surrogate(key, cfg: DASOConfig):
    dims = [feature_size(cfg)] + [cfg.hidden] * cfg.depth + [1]
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": jax.random.normal(k, (a, b)) / jnp.sqrt(a),
             "b": jnp.zeros((b,))}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def surrogate_apply(theta, x):
    for i, layer in enumerate(theta):
        x = x @ layer["w"] + layer["b"]
        if i < len(theta) - 1:
            x = jnp.tanh(x)
    return x[..., 0]


def pack_input(cfg: DASOConfig, state, placement, decisions, mask):
    """state (W, F); placement logits (C, W); decisions (C,) in {0,1};
    mask (C,) active containers."""
    d1 = jax.nn.one_hot(decisions, 2) * mask[:, None]
    p = jax.nn.softmax(placement, axis=-1) * mask[:, None]
    if not cfg.decision_aware:
        d1 = jnp.zeros_like(d1)
    return jnp.concatenate([state.reshape(-1), p.reshape(-1), d1.reshape(-1)])


# --------------------------------------------------------------- training

@functools.partial(jax.jit, static_argnums=(0,))
def train_epoch(cfg: DASOConfig, theta, opt_state, xs, ys):
    """One epoch of MSE training (eq. 11) over a batch of packed inputs."""
    def loss(theta):
        pred = surrogate_apply(theta, xs)
        return jnp.mean(jnp.square(pred - ys))

    l, g = jax.value_and_grad(loss)(theta)
    theta, opt_state = adamw_update(g, opt_state, theta, cfg.lr_train,
                                    weight_decay=0.0)
    return theta, opt_state, l


@functools.partial(jax.jit, static_argnums=(0,))
def train_epoch_weighted(cfg: DASOConfig, theta, opt_state, xs, ys, w):
    """Shape-stable variant of ``train_epoch``: ``xs``/``ys`` are padded
    to a fixed window and ``w`` masks the real rows, so the online
    finetuning loop compiles once per config instead of once per replay
    length.  With 0/1 weights the loss equals the unpadded MSE."""
    def loss(theta):
        pred = surrogate_apply(theta, xs)
        return jnp.sum(w * jnp.square(pred - ys)) / jnp.maximum(
            jnp.sum(w), 1.0)

    l, g = jax.value_and_grad(loss)(theta)
    theta, opt_state = adamw_update(g, opt_state, theta, cfg.lr_train,
                                    weight_decay=0.0)
    return theta, opt_state, l


def make_trainer(cfg: DASOConfig, key):
    theta = init_surrogate(key, cfg)
    opt_state = adamw_init(theta)
    return theta, opt_state


# ------------------------------------------------- online finetuning carry
#
# The in-kernel training loop (repro.env.jaxsim, mode="train") threads the
# DASO trainer through the jitted interval carry: a fixed REPLAY_WINDOW-row
# rolling window of (packed placement features, O^P target) pairs plus the
# (theta, AdamW opt_state) pair train_epoch_weighted advances.  Everything
# below is a pure function shared verbatim by the kernel and the host-side
# parity replay (reference.replay_trace_edgesim_trained), which is what
# makes the finetuned-theta trajectory reproducible across backends.

#: fixed replay-window rows — matches the host ``SurrogatePlacer``'s
#: shape-stable 64-row training window
REPLAY_WINDOW = 64

#: place-stage gate: ascend the surrogate only once this many interval
#: records exist (cold start keeps the warm/BestFit placement), and train
#: only once ``TRAIN_MIN`` records exist — the host placer's thresholds
PLACE_MIN, TRAIN_MIN = 32, 8


def window_init(cfg: DASOConfig, dtype=jnp.float64):
    """Empty replay window: (xs, ys, count) as a flat dict pytree."""
    return {"xs": jnp.zeros((REPLAY_WINDOW, feature_size(cfg)), dtype),
            "ys": jnp.zeros((REPLAY_WINDOW,), dtype),
            "count": jnp.zeros((), jnp.int32)}


def window_append(win, x, y):
    """Append one (x, y) record, oldest-first, dropping the oldest row
    once the window is full — the array form of the host placer's
    ``replay[-64:]`` list slice (row order is part of the shared
    contract, so both backends feed ``train_epoch_weighted`` identical
    operands)."""
    full = win["count"] >= REPLAY_WINDOW
    idx = jnp.minimum(win["count"], REPLAY_WINDOW - 1)
    xs = jnp.where(full, jnp.roll(win["xs"], -1, axis=0), win["xs"])
    ys = jnp.where(full, jnp.roll(win["ys"], -1), win["ys"])
    return {"xs": xs.at[idx].set(x.astype(xs.dtype)),
            "ys": ys.at[idx].set(y.astype(ys.dtype)),
            "count": jnp.minimum(win["count"] + 1, REPLAY_WINDOW)}


def op_objective(resp, sla, acc, fin_mask, cpu_util, interval_s: float,
                 alpha: float = 0.5, beta: float = 0.5):
    """The per-interval training target O^P = O^MAB − α·AEC − β·ART
    (eq. 10) over masked fixed-width arrays.

    ``fin_mask`` selects the tasks that finished this interval (their
    reward mean is O^MAB, their response mean feeds ART); an empty
    interval contributes O^MAB = ART = 0 exactly as the host
    ``MABDecider.interval_reward`` / ``SurrogatePlacer.feedback`` pair.
    """
    finf = fin_mask.astype(resp.dtype)
    nfin = jnp.sum(finf)
    d = jnp.maximum(nfin, 1.0)
    o_mab = jnp.sum(finf * ((resp <= sla).astype(resp.dtype) + acc))
    o_mab = jnp.where(nfin > 0, 0.5 * o_mab / d, 0.0)
    aec = jnp.mean(cpu_util)
    art = jnp.where(nfin > 0,
                    jnp.sum(finf * resp) / d / (6.0 * interval_s), 0.0)
    return o_mab - alpha * aec - beta * jnp.minimum(art, 1.0)


def finetune_window(cfg: DASOConfig, theta, opt_state, win,
                    train_steps: int = 4, train_min: int = TRAIN_MIN):
    """Advance (theta, opt_state) by ``train_steps`` weighted epochs over
    the replay window — a no-op until ``train_min`` records exist (the
    cold-start gate of the host placer's ``feedback``; ``TRAIN_MIN``
    matches its default)."""
    w = (jnp.arange(REPLAY_WINDOW) < win["count"]).astype(win["ys"].dtype)

    def train(args):
        theta, opt_state = args
        for _ in range(train_steps):
            theta, opt_state, _ = train_epoch_weighted(
                cfg, theta, opt_state, win["xs"], win["ys"], w)
        return theta, opt_state

    return jax.lax.cond(win["count"] >= train_min, train,
                        lambda args: args, (theta, opt_state))


def window_loss(cfg: DASOConfig, theta, win):
    """The weighted replay-window MSE ``train_epoch_weighted`` descends,
    evaluated without taking a step — the train engine's
    ``daso_last_loss`` telemetry column.  With an empty window every
    weight is zero and the loss is exactly 0.  Shared verbatim by the
    kernel engine and the host parity replay, so the telemetry series
    agree across backends."""
    w = (jnp.arange(REPLAY_WINDOW) < win["count"]).astype(win["ys"].dtype)
    pred = surrogate_apply(theta, win["xs"])
    return jnp.sum(w * jnp.square(pred - win["ys"])) / jnp.maximum(
        jnp.sum(w), 1.0)


# -------------------------------------------------------------- placement

@functools.partial(jax.jit, static_argnums=(0,))
def optimize_placement(cfg: DASOConfig, theta, state, placement0, decisions,
                       mask):
    """Gradient ascent of the surrogate w.r.t. placement logits (eq. 12).

    Iterates with momentum until the L2 step norm falls below tol (or
    place_iters), mirroring GOBI's converged-iteration rule.
    """
    def score(p):
        return surrogate_apply(theta, pack_input(cfg, state, p, decisions,
                                                 mask))

    def cond(carry):
        p, vel, i, delta = carry
        return jnp.logical_and(i < cfg.place_iters, delta > cfg.tol)

    def body(carry):
        p, vel, i, _ = carry
        g = jax.grad(score)(p)
        vel = cfg.momentum * vel + g
        new_p = p + cfg.lr_place * vel          # ascent: maximize O^P
        delta = jnp.linalg.norm(new_p - p)
        return new_p, vel, i + 1, delta

    p, _, iters, _ = jax.lax.while_loop(
        cond, body, (placement0, jnp.zeros_like(placement0),
                     jnp.asarray(0), jnp.asarray(jnp.inf)))
    return p, score(p), iters


def placement_to_assignment(placement_logits, mask):
    """Row argmax -> worker index per container (-1 for inactive rows)."""
    idx = jnp.argmax(placement_logits, axis=-1)
    return jnp.where(mask.astype(bool), idx, -1)


def warm_start_logits(cfg: DASOConfig, warm_workers, row_valid):
    """(C,) warm-start worker per container row -> (C, W) logits: 2.0 at
    the warm worker of each valid row, zeros elsewhere.

    This is the shared eq.-12 initialization (iterate from the previous /
    BestFit placement) used by both the host-side parity replay and the
    in-kernel array-form DASO stage, so their ``optimize_placement``
    inputs are identical.  dtype follows the ambient default float (the
    learned-policy paths run it under ``enable_x64``).
    """
    oh = (warm_workers[:, None] == jnp.arange(cfg.num_workers)) \
        & row_valid[:, None]
    return oh * 2.0
