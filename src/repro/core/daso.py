"""DASO — Decision-Aware Surrogate Optimization placement module (§4.2).

An FCN surrogate f([S_t, P_t, D_t]; θ) predicts the QoS objective
O^P = O^MAB − α·AEC − β·ART (eq. 10).  It is trained with MSE (eq. 11,
AdamW) on execution traces, then the placement is found by gradient ascent
of the surrogate output w.r.t. a relaxed placement matrix (eq. 12), with
momentum/annealing as in GOBI, followed by feasibility repair.

The placement matrix is relaxed to logits (C_max × H); the simulator
consumes the row-argmax.  "Decision-aware" = the per-container split
decision one-hot is part of the surrogate input; the vanilla GOBI ablation
(M+G / S+G / L+G baselines) simply zeroes that slice.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import adamw_init, adamw_update


class DASOConfig(NamedTuple):
    num_workers: int
    max_containers: int
    state_features: int          # per-worker utilization features
    hidden: int = 128
    depth: int = 3
    lr_train: float = 1e-3
    lr_place: float = 0.1
    place_iters: int = 50
    momentum: float = 0.9
    tol: float = 1e-3
    decision_aware: bool = True


def feature_size(cfg: DASOConfig) -> int:
    # worker utilization state + placement logits + split-decision one-hots
    return (cfg.num_workers * cfg.state_features
            + cfg.max_containers * cfg.num_workers
            + cfg.max_containers * 2)


def init_surrogate(key, cfg: DASOConfig):
    dims = [feature_size(cfg)] + [cfg.hidden] * cfg.depth + [1]
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": jax.random.normal(k, (a, b)) / jnp.sqrt(a),
             "b": jnp.zeros((b,))}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def surrogate_apply(theta, x):
    for i, layer in enumerate(theta):
        x = x @ layer["w"] + layer["b"]
        if i < len(theta) - 1:
            x = jnp.tanh(x)
    return x[..., 0]


def pack_input(cfg: DASOConfig, state, placement, decisions, mask):
    """state (W, F); placement logits (C, W); decisions (C,) in {0,1};
    mask (C,) active containers."""
    d1 = jax.nn.one_hot(decisions, 2) * mask[:, None]
    p = jax.nn.softmax(placement, axis=-1) * mask[:, None]
    if not cfg.decision_aware:
        d1 = jnp.zeros_like(d1)
    return jnp.concatenate([state.reshape(-1), p.reshape(-1), d1.reshape(-1)])


# --------------------------------------------------------------- training

@functools.partial(jax.jit, static_argnums=(0,))
def train_epoch(cfg: DASOConfig, theta, opt_state, xs, ys):
    """One epoch of MSE training (eq. 11) over a batch of packed inputs."""
    def loss(theta):
        pred = surrogate_apply(theta, xs)
        return jnp.mean(jnp.square(pred - ys))

    l, g = jax.value_and_grad(loss)(theta)
    theta, opt_state = adamw_update(g, opt_state, theta, cfg.lr_train,
                                    weight_decay=0.0)
    return theta, opt_state, l


@functools.partial(jax.jit, static_argnums=(0,))
def train_epoch_weighted(cfg: DASOConfig, theta, opt_state, xs, ys, w):
    """Shape-stable variant of ``train_epoch``: ``xs``/``ys`` are padded
    to a fixed window and ``w`` masks the real rows, so the online
    finetuning loop compiles once per config instead of once per replay
    length.  With 0/1 weights the loss equals the unpadded MSE."""
    def loss(theta):
        pred = surrogate_apply(theta, xs)
        return jnp.sum(w * jnp.square(pred - ys)) / jnp.maximum(
            jnp.sum(w), 1.0)

    l, g = jax.value_and_grad(loss)(theta)
    theta, opt_state = adamw_update(g, opt_state, theta, cfg.lr_train,
                                    weight_decay=0.0)
    return theta, opt_state, l


def make_trainer(cfg: DASOConfig, key):
    theta = init_surrogate(key, cfg)
    opt_state = adamw_init(theta)
    return theta, opt_state


# -------------------------------------------------------------- placement

@functools.partial(jax.jit, static_argnums=(0,))
def optimize_placement(cfg: DASOConfig, theta, state, placement0, decisions,
                       mask):
    """Gradient ascent of the surrogate w.r.t. placement logits (eq. 12).

    Iterates with momentum until the L2 step norm falls below tol (or
    place_iters), mirroring GOBI's converged-iteration rule.
    """
    def score(p):
        return surrogate_apply(theta, pack_input(cfg, state, p, decisions,
                                                 mask))

    def cond(carry):
        p, vel, i, delta = carry
        return jnp.logical_and(i < cfg.place_iters, delta > cfg.tol)

    def body(carry):
        p, vel, i, _ = carry
        g = jax.grad(score)(p)
        vel = cfg.momentum * vel + g
        new_p = p + cfg.lr_place * vel          # ascent: maximize O^P
        delta = jnp.linalg.norm(new_p - p)
        return new_p, vel, i + 1, delta

    p, _, iters, _ = jax.lax.while_loop(
        cond, body, (placement0, jnp.zeros_like(placement0),
                     jnp.asarray(0), jnp.asarray(jnp.inf)))
    return p, score(p), iters


def placement_to_assignment(placement_logits, mask):
    """Row argmax -> worker index per container (-1 for inactive rows)."""
    idx = jnp.argmax(placement_logits, axis=-1)
    return jnp.where(mask.astype(bool), idx, -1)


def warm_start_logits(cfg: DASOConfig, warm_workers, row_valid):
    """(C,) warm-start worker per container row -> (C, W) logits: 2.0 at
    the warm worker of each valid row, zeros elsewhere.

    This is the shared eq.-12 initialization (iterate from the previous /
    BestFit placement) used by both the host-side parity replay and the
    in-kernel array-form DASO stage, so their ``optimize_placement``
    inputs are identical.  dtype follows the ambient default float (the
    learned-policy paths run it under ``enable_x64``).
    """
    oh = (warm_workers[:, None] == jnp.arange(cfg.num_workers)) \
        & row_valid[:, None]
    return oh * 2.0
