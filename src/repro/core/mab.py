"""Multi-Armed-Bandit split-decision module (paper §4.1, eqs. 2–9).

Two context-separated bandits:
  * ``h`` — high-SLA context: the task's deadline exceeds the EMA estimate
    R^a of the layer-split response time for its application type.
  * ``l`` — low-SLA context: deadline below the estimate.

Each context holds Q-estimates and decision counts for the two arms
(L = layer split, S = semantic split).  Training uses feedback-based
ε-greedy (ε decays and the reward threshold ρ grows whenever the average
MAB reward exceeds ρ — RBED, eqs. 7–8); deployment uses UCB (eq. 9).

State is a flat pytree of jnp scalars/arrays so the whole module jits and
checkpoints like any other model state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

LAYER, SEMANTIC = 0, 1        # arm indices
HIGH, LOW = 0, 1              # context indices


class MABState(NamedTuple):
    Q: jnp.ndarray            # (2 contexts, 2 arms) reward estimates
    N: jnp.ndarray            # (2, 2) decision counts
    R: jnp.ndarray            # (num_apps,) EMA layer-split response time
    eps: jnp.ndarray          # scalar, exploration prob (train)
    rho: jnp.ndarray          # scalar, reward threshold (RBED)
    t: jnp.ndarray            # scheduling-interval counter


def init_state(num_apps: int, eps0: float = 1.0, rho0: float = 0.05) -> MABState:
    return MABState(
        Q=jnp.zeros((2, 2), jnp.float32),
        N=jnp.zeros((2, 2), jnp.float32),
        R=jnp.zeros((num_apps,), jnp.float32),
        eps=jnp.asarray(eps0, jnp.float32),
        rho=jnp.asarray(rho0, jnp.float32),
        t=jnp.asarray(1, jnp.int32),
    )


def context_of(state: MABState, sla, app):
    """HIGH if sla >= R^app else LOW (eq. contexts of §4.1.2)."""
    return jnp.where(sla >= state.R[app], HIGH, LOW).astype(jnp.int32)


def update_response_estimates(state: MABState, apps, resp, was_layer,
                              phi: float = 0.9) -> MABState:
    """EMA update of R^a (eq. 2) for leaving layer-split tasks.

    apps (n,) int32, resp (n,) float32, was_layer (n,) bool.  Exponential
    moving average with multiplier phi on the newest observation, applied
    per leaving task via a scan (matching the paper's per-task update).
    """
    def step(R, inp):
        a, r, w = inp
        new = phi * r + (1.0 - phi) * R[a]
        return R.at[a].set(jnp.where(w, new, R[a])), None

    R, _ = jax.lax.scan(step, state.R, (apps, resp, was_layer))
    return state._replace(R=R)


def interval_rewards(state: MABState, apps, sla, resp, acc, decisions):
    """Per-(context, arm) reward metrics O^{c,d} for one interval (eqs. 3–4).

    Reward of a task = (1[r_i <= sla_i] + p_i) / 2; averaged over the tasks
    that fall in each (context, arm) bucket.  Returns (O (2,2), counts (2,2)).
    """
    ctx = jnp.where(sla >= state.R[apps], HIGH, LOW)
    per_task = (0.5 * ((resp <= sla).astype(jnp.float32) + acc))
    O = jnp.zeros((2, 2), jnp.float32)
    cnt = jnp.zeros((2, 2), jnp.float32)
    sel = jnp.stack([ctx, decisions], axis=-1)
    cnt = cnt.at[sel[:, 0], sel[:, 1]].add(1.0)
    O = O.at[sel[:, 0], sel[:, 1]].add(per_task)
    O = jnp.where(cnt > 0, O / jnp.maximum(cnt, 1.0), 0.0)
    return O, cnt


def update_q(state: MABState, O, cnt, gamma: float = 0.3) -> MABState:
    """Q <- Q + gamma (O - Q) where data exists (eq. 5), N += counts."""
    Q = jnp.where(cnt > 0, state.Q + gamma * (O - state.Q), state.Q)
    return state._replace(Q=Q, N=state.N + cnt)


def rbed_update(state: MABState, O, cnt, k: float = 0.1) -> MABState:
    """Feedback-based ε decay / ρ increment (eqs. 7–8)."""
    have = cnt > 0
    o_mab = jnp.where(jnp.any(have),
                      jnp.sum(jnp.where(have, O, 0.0)) / jnp.maximum(have.sum(), 1),
                      0.0)
    improve = o_mab > state.rho
    eps = jnp.where(improve, (1.0 - k) * state.eps, state.eps)
    rho = jnp.where(improve, (1.0 + k) * state.rho, state.rho)
    return state._replace(eps=eps, rho=rho)


def decide_train(state: MABState, key, sla, app):
    """ε-greedy training decision (eq. 6).  Scalar task -> arm index."""
    ctx = context_of(state, sla, app)
    greedy = jnp.argmax(state.Q[ctx]).astype(jnp.int32)
    k1, k2 = jax.random.split(key)
    rand = jax.random.bernoulli(k1, state.eps)
    coin = jax.random.bernoulli(k2, 0.5).astype(jnp.int32)
    return jnp.where(rand, coin, greedy), ctx


def decide_ucb(state: MABState, sla, app, c: float = 0.5):
    """UCB deployment decision (eq. 9)."""
    ctx = context_of(state, sla, app)
    bonus = c * jnp.sqrt(jnp.log(jnp.maximum(state.t.astype(jnp.float32), 2.0))
                         / jnp.maximum(state.N[ctx], 1.0))
    return jnp.argmax(state.Q[ctx] + bonus).astype(jnp.int32), ctx


decide_train_batch = jax.vmap(decide_train, in_axes=(None, 0, 0, 0))
decide_ucb_batch = jax.vmap(decide_ucb, in_axes=(None, 0, 0, None))


def decide_train_rows(state: MABState, key_t, sla, app):
    """ε-greedy training decisions (eq. 6) for one interval's rows.

    Row ``a`` draws from ``fold_in(key_t, a)``, so row keys are
    *prefix-stable* in the row count: the jitted kernel calling this on
    padded ``(A,)`` arrival arrays and the host parity replay calling it
    on the dense valid prefix see bit-identical keys (and therefore
    decisions) for every real row — padding rows burn no shared
    randomness.  This is the key-threading contract the in-kernel
    training carry relies on (the per-interval ``key_t`` itself comes
    from ``fold_in(trace_key, t)``).
    """
    keys = jax.vmap(lambda a: jax.random.fold_in(key_t, a))(
        jnp.arange(sla.shape[0], dtype=jnp.uint32))
    return decide_train_batch(state, keys, sla, app)


# ------------------------------------------------------ masked (array) form
#
# The jitted simulator (repro.env.jaxsim) carries MABState through a
# fixed-capacity slot store, so its per-interval feedback arrives as
# fixed-width arrays with a validity mask rather than dense lists of
# finished tasks.  The masked functions below are that shared
# implementation: the in-kernel feedback calls them with (K,)-wide
# slot-ordered arrays, the host-side parity replay calls them with the
# same values densely packed — masked-out rows contribute exactly 0 to
# every reduction and no-op every sequential update, so both callers see
# identical state trajectories (reductions run in float64 internally so
# the float32 results round identically regardless of padding length).


def interval_rewards_masked(state: MABState, apps, sla, resp, acc,
                            decisions, mask):
    """``interval_rewards`` over masked fixed-width arrays.

    Rows with ``mask`` False are ignored (their values only ever multiply
    a 0.0 weight).  Bucketing runs as masked one-hot sums instead of
    scatter-adds so the accumulation is deterministic under jit/vmap;
    the weights are weak-typed, so under the jitted backend's
    ``enable_x64`` scope the reductions accumulate in float64 (whose
    float32 casts round identically for kernel and replay) and in plain
    float32 elsewhere.
    """
    ctx = jnp.where(sla >= state.R[apps], HIGH, LOW)
    per_task = 0.5 * ((resp <= sla).astype(jnp.float32) + acc)
    w = (mask[:, None, None]
         & (ctx[:, None] == jnp.arange(2))[:, :, None]
         & (decisions[:, None] == jnp.arange(2))[:, None, :]) * 1.0
    cnt = jnp.sum(w, axis=0)
    O = jnp.sum(w * per_task[:, None, None], axis=0)
    O = jnp.where(cnt > 0, O / jnp.maximum(cnt, 1.0), 0.0)
    return O.astype(jnp.float32), cnt.astype(jnp.float32)


def end_of_interval_masked(state: MABState, apps, sla, resp, acc, decisions,
                           mask, phi: float = 0.9, gamma: float = 0.3,
                           k: float = 0.1) -> MABState:
    """Algorithm-1 end-of-interval bookkeeping over masked arrays.

    Equivalent to ``end_of_interval`` on the masked-in rows (the EMA scan
    reuses ``update_response_estimates`` directly — masked-out rows pass
    ``was_layer=False`` and leave R untouched).  With an all-False mask
    this degrades to the empty-interval update: ``t += 1`` only.
    """
    state = update_response_estimates(
        state, apps, resp, mask & (decisions == LAYER), phi)
    O, cnt = interval_rewards_masked(state, apps, sla, resp, acc,
                                     decisions, mask)
    state = update_q(state, O, cnt, gamma)
    state = rbed_update(state, O, cnt, k)
    return state._replace(t=state.t + 1)


def end_of_interval(state: MABState, apps, sla, resp, acc, decisions,
                    phi: float = 0.9, gamma: float = 0.3,
                    k: float = 0.1) -> MABState:
    """Full Algorithm-1 bookkeeping for the tasks leaving this interval."""
    state = update_response_estimates(state, apps, resp,
                                      decisions == LAYER, phi)
    O, cnt = interval_rewards(state, apps, sla, resp, acc, decisions)
    state = update_q(state, O, cnt, gamma)
    state = rbed_update(state, O, cnt, k)
    return state._replace(t=state.t + 1)


# ----------------------------------------------- Gillis baseline (array form)
#
# The Gillis baseline (§2.1) decides layer-split vs model compression with
# a contextual Q-learner: context = (app, deadline bucket vs 1.6× the
# unloaded layer-chain reference), ε-greedy arm choice with multiplicative
# ε-decay per scheduling interval, and a per-leaving-task TD(0) update
# Q ← Q + lr·(r − Q).  The functions below are the shared pure form run
# by BOTH the jitted kernel (``repro.env.jaxsim.kernels.gillis_*``) and
# the host parity replay (``reference.replay_trace_edgesim_gillis``) —
# the same role ``decide_train_rows``/``end_of_interval_masked`` play for
# the SplitPlace MAB.  The key choreography mirrors ``decide_train_rows``
# (per-row ``fold_in``, prefix-stable in the padded row count), so the
# object-loop ``splitplace.GillisDecider`` (NumPy ``RandomState``) stays
# the host-backend baseline while these are the in-kernel one.

#: Gillis Q-table arms (second axis of the (apps, 2, 2) table)
GILLIS_LAYER_ARM, GILLIS_COMPRESS_ARM = 0, 1


def gillis_init(num_apps: int, dtype=jnp.float64):
    """Zero-initialized contextual Q-table, matching ``GillisDecider``."""
    return jnp.zeros((num_apps, 2, 2), dtype)


def gillis_bucket(sla, batch, app, layer_ref):
    """Deadline context bucket: 1 when the SLA undercuts 1.6× the
    batch-scaled unloaded layer-chain reference (``GillisDecider._ctx``).
    ``layer_ref`` is the (num_apps,) ``layer_ref_response_s`` table."""
    ref = layer_ref[app] * batch / 40000.0 * 1.6
    return (sla < ref).astype(jnp.int32)


def gillis_decide_rows(Q, eps, key_t, sla, batch, app, layer_ref):
    """ε-greedy Gillis arm decisions for one interval's rows.

    Row ``a`` draws from ``fold_in(key_t, a)`` — the same prefix-stable
    choreography as ``decide_train_rows``, so the jitted kernel (padded
    ``(A,)`` rows) and the host replay (dense valid prefix) see
    bit-identical bits per real row.  Returns (arms, buckets); arm 0 is
    the layer split, arm 1 the compressed model.
    """
    bucket = gillis_bucket(sla, batch, app, layer_ref)

    def one(key, ap, b):
        k1, k2 = jax.random.split(key)
        explore = jax.random.bernoulli(k1, eps)
        coin = jax.random.bernoulli(k2, 0.5).astype(jnp.int32)
        greedy = jnp.argmax(Q[ap, b]).astype(jnp.int32)
        return jnp.where(explore, coin, greedy)

    keys = jax.vmap(lambda a: jax.random.fold_in(key_t, a))(
        jnp.arange(sla.shape[0], dtype=jnp.uint32))
    return jax.vmap(one)(keys, app, bucket), bucket


def gillis_update_masked(Q, apps, buckets, arms, rewards, mask, lr):
    """Per-leaving-task sequential TD(0) Q-update over masked rows.

    The host decider iterates its finished list in order, so later tasks
    of the same (app, bucket, arm) cell see earlier updates — the scan
    preserves that sequencing exactly; masked-out rows no-op.
    """
    def step(Q, inp):
        a, b, m, r, w = inp
        cur = Q[a, b, m]
        new = cur + lr * (r - cur)
        return Q.at[a, b, m].set(jnp.where(w, new, cur)), None

    Q, _ = jax.lax.scan(step, Q, (apps, buckets, arms, rewards, mask))
    return Q
