"""Deterministic synthetic data pipelines (offline container — no downloads).

Two families:
  * token streams for LM training of the assigned architectures;
  * class-structured "image" vectors for the paper's edge applications
    (MNIST / FashionMNIST / CIFAR100 stand-ins with matching input dims and
    class counts), used to train and evaluate the real split networks.

Both are sharded-friendly: batches are produced on host as numpy and can be
device_put with any NamedSharding.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """The paper's application set A = {MNIST, FashionMNIST, CIFAR100}."""
    name: str
    input_dim: int
    num_classes: int
    difficulty: float       # controls class separability (higher = harder)
    container_mb: tuple     # split-fragment image sizes from §6.2


APPS = {
    "mnist": AppSpec("mnist", 28 * 28, 10, 0.8, (8, 14)),
    "fashionmnist": AppSpec("fashionmnist", 28 * 28, 10, 1.6, (34, 56)),
    "cifar100": AppSpec("cifar100", 32 * 32 * 3, 100, 1.0, (47, 76)),
}
APP_NAMES = list(APPS)


def synthetic_classification(app: str, n: int, seed: int = 0):
    """Gaussian class clusters on a random manifold; deterministic.

    Class centers depend only on the app (so train/test seeds share the
    same task); the seed drives sampling noise and label draws.
    """
    spec = APPS[app]
    centers_rng = np.random.RandomState(abs(hash(app)) % 2**31)
    centers = centers_rng.randn(spec.num_classes,
                                spec.input_dim).astype(np.float32)
    centers *= 2.0 / np.sqrt(spec.input_dim)
    rng = np.random.RandomState((abs(hash(app)) % 2**31) ^ (seed + 1))
    y = rng.randint(0, spec.num_classes, n)
    noise = rng.randn(n, spec.input_dim).astype(np.float32)
    x = centers[y] + spec.difficulty * 0.35 * noise
    return x.astype(np.float32), y.astype(np.int32)


class TokenPipeline:
    """Deterministic pseudo-corpus LM batches with a learnable structure:
    a noisy order-2 Markov chain over the vocab so that training actually
    reduces loss (pure-uniform tokens would be unlearnable)."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, num_codebooks: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.cb = num_codebooks
        self.rng = np.random.RandomState(seed)
        v = min(vocab_size, 4096)
        self._v = v
        # sparse successor structure: each token has 8 likely successors
        self._succ = self.rng.randint(0, v, (v, 8))

    def next_batch(self):
        shape = (self.batch, self.seq + 1)
        v = self._v
        toks = np.empty(shape, np.int64)
        toks[:, 0] = self.rng.randint(0, v, self.batch)
        choice = self.rng.randint(0, 8, shape)
        noise = self.rng.rand(*shape) < 0.1
        rand_tok = self.rng.randint(0, v, shape)
        for t in range(1, self.seq + 1):
            nxt = self._succ[toks[:, t - 1], choice[:, t]]
            toks[:, t] = np.where(noise[:, t], rand_tok[:, t], nxt)
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        if self.cb:
            tokens = np.stack([(tokens + i * 7) % self.vocab
                               for i in range(self.cb)], axis=-1)
            labels = np.stack([(labels + i * 7) % self.vocab
                               for i in range(self.cb)], axis=-1)
        return {"tokens": tokens, "labels": labels}
