import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input shape) on the
production mesh, prove memory fits, and extract roofline terms.

Single combo:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single --out out.json
Full sweep (subprocess per combo for isolation):
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import subprocess
import sys
import time

import numpy as np


DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(m):
    dt, dims = m.group(1), m.group(2)
    if dt not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str):
    """Per-device bytes moved by collectives: sum of result-shape sizes of
    every collective op (start/done pairs counted once)."""
    totals = {op: 0 for op in COLLECTIVE_OPS}
    counts = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        for op in COLLECTIVE_OPS:
            token = f" {op}("
            start = f" {op}-start("
            if token in line or start in line:
                # result type sits between '=' and the op name
                rhs = line.split("=", 1)[-1]
                typestr = rhs.split(op, 1)[0]
                b = sum(shape_bytes(m) for m in _SHAPE_RE.finditer(typestr))
                totals[op] += b
                counts[op] += 1
                break
    return totals, counts


def _parse_val(v):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return v == "True"
    return v


def apply_overrides(cfg, sets):
    """--set moe.dispatch=gather --set attn_causal_skip=True ..."""
    import dataclasses
    for kv in sets or []:
        key, val = kv.split("=", 1)
        val = _parse_val(val)
        if "." in key:
            sub, field = key.split(".", 1)
            subcfg = dataclasses.replace(getattr(cfg, sub), **{field: val})
            cfg = dataclasses.replace(cfg, **{sub: subcfg})
        else:
            cfg = dataclasses.replace(cfg, **{key: val})
    return cfg


def lower_one(arch: str, shape_name: str, multi_pod: bool, sets=None):
    import jax
    from repro.configs import INPUT_SHAPES, get_config
    from repro.launch import sharding, specs, steps
    from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                                   make_production_mesh, num_chips)
    from repro.optim.optimizers import make_optimizer

    cfg = get_config(arch)
    cfg = apply_overrides(cfg, sets)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = num_chips(mesh)
    kind = INPUT_SHAPES[shape_name]["kind"]
    seq = INPUT_SHAPES[shape_name]["seq_len"]
    gbatch = INPUT_SHAPES[shape_name]["global_batch"]

    p_shape = specs.params_specs(cfg)
    p_shard = sharding.params_shardings(mesh, cfg, p_shape)
    t0 = time.time()
    if kind == "train":
        init_opt, _ = make_optimizer(cfg.optimizer)
        opt_shape = jax.eval_shape(init_opt, p_shape)
        opt_shard = sharding.opt_state_shardings(mesh, cfg, opt_shape, p_shape)
        batch = specs.input_specs(cfg, shape_name)["batch"]
        b_shard = sharding.batch_shardings(mesh, batch)
        step = steps.make_train_step(cfg, mesh)
        jit = jax.jit(step, in_shardings=(p_shard, opt_shard, b_shard),
                      out_shardings=(p_shard, opt_shard, None),
                      donate_argnums=(0, 1))
        lowered = jit.lower(p_shape, opt_shape, batch)
    elif kind == "prefill":
        batch = specs.input_specs(cfg, shape_name)["batch"]
        b_shard = sharding.batch_shardings(mesh, batch)
        step = steps.make_prefill_step(cfg, mesh)
        jit = jax.jit(step, in_shardings=(p_shard, b_shard))
        lowered = jit.lower(p_shape, batch)
    else:  # decode
        sp = specs.input_specs(cfg, shape_name)
        tok_shard = sharding.batch_shardings(mesh, sp["tokens"])
        cache_shard = sharding.cache_shardings(mesh, cfg, sp["cache"])
        ex_shard = sharding.batch_shardings(mesh, sp["extras"])
        step = steps.make_serve_step(cfg, mesh)
        jit = jax.jit(step,
                      in_shardings=(p_shard, tok_shard, cache_shard, None,
                                    ex_shard),
                      out_shardings=(None, cache_shard),
                      donate_argnums=(2,))
        lowered = jit.lower(p_shape, sp["tokens"], sp["cache"], sp["pos"],
                            sp["extras"])
    lower_s = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    # trip-count-aware static count over the global (unsharded) step —
    # XLA's cost_analysis visits while bodies once (see flopcount.py)
    from repro.launch.flopcount import count_fn
    if kind == "train":
        flops_g, bytes_g = count_fn(step, p_shape, opt_shape, batch)
    elif kind == "prefill":
        flops_g, bytes_g = count_fn(step, p_shape, batch)
    else:
        flops_g, bytes_g = count_fn(step, p_shape, sp["tokens"], sp["cache"],
                                    sp["pos"], sp["extras"])

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll, coll_n = collective_bytes(compiled.as_text())
    coll_dev = float(sum(coll.values()))

    # tokens processed per step (global)
    if kind == "train":
        tokens = gbatch * seq
        mf_factor = 6.0
    elif kind == "prefill":
        tokens = gbatch * seq
        mf_factor = 2.0
    else:
        tokens = gbatch
        mf_factor = 2.0
    n_active = cfg.active_param_count()
    model_flops = mf_factor * n_active * tokens

    compute_s = flops_g / (chips * PEAK_FLOPS_BF16)
    memory_s = bytes_g / (chips * HBM_BW)
    collective_s = coll_dev / ICI_BW       # per-device bytes over link bw

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "kind": kind, "seq": seq, "global_batch": gbatch,
        "lower_s": round(lower_s, 1), "compile_s": round(compile_s, 1),
        "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collective_breakdown": coll, "collective_counts": coll_n,
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 2**30,
            "output_gb": mem.output_size_in_bytes / 2**30,
            "temp_gb": mem.temp_size_in_bytes / 2**30,
            "code_mb": mem.generated_code_size_in_bytes / 2**20,
            "peak_gb": (mem.argument_size_in_bytes
                        + mem.temp_size_in_bytes) / 2**30,
        },
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s,
            "bottleneck": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)], key=lambda kv: kv[1])[0],
        },
        "model_flops": model_flops,
        "counted_flops_global": flops_g,
        "counted_bytes_global": bytes_g,
        "useful_flops_ratio": model_flops / max(flops_g, 1.0),
        "params": cfg.param_count(),
        "active_params": n_active,
    }
    return result


ALL_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_all(archs=None, shapes=None, meshes=("single", "multi"),
            out_dir="benchmarks/results/dryrun", timeout=3600):
    from repro.configs import ASSIGNED_ARCHS
    os.makedirs(out_dir, exist_ok=True)
    archs = archs or ASSIGNED_ARCHS
    shapes = shapes or ALL_SHAPES
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                tag = f"{arch}_{shape}_{mesh}".replace("/", "-")
                out = os.path.join(out_dir, tag + ".json")
                if os.path.exists(out):
                    print(f"skip {tag} (cached)")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh,
                       "--out", out]
                print(f"== {tag}", flush=True)
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=timeout,
                                   env={**os.environ, "PYTHONPATH": "src"})
                if r.returncode != 0:
                    failures.append(tag)
                    print(f"FAIL {tag}\n{r.stdout[-2000:]}\n{r.stderr[-4000:]}")
                else:
                    print(f"ok {tag} ({time.time()-t0:.0f}s)")
    print(f"done; {len(failures)} failures: {failures}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=ALL_SHAPES)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--out")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", nargs="*")
    ap.add_argument("--shapes", nargs="*")
    ap.add_argument("--meshes", nargs="*", default=["single", "multi"])
    ap.add_argument("--set", action="append", default=None,
                    help="config overrides, e.g. --set moe.dispatch=gather")
    args = ap.parse_args()
    if args.all:
        fails = run_all(args.archs or None, args.shapes or None,
                        tuple(args.meshes))
        sys.exit(1 if fails else 0)
    res = lower_one(args.arch, args.shape, args.mesh == "multi",
                    sets=getattr(args, "set", None))
    print(json.dumps(res, indent=2, default=float))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2, default=float)


if __name__ == "__main__":
    main()
