"""Static FLOP/byte counter over jaxprs — trip-count-aware.

XLA's HloCostAnalysis visits a ``while`` body once, so any scan-structured
model (scan-over-layers, grad-accumulation, blockwise attention) is
undercounted by the trip count.  This counter walks the closed jaxpr of
the step function instead, multiplying scan bodies by their length, and
produces:

  * flops        — 2*M*N*K for dot_general (everything else 1 flop/elem)
  * hbm_bytes    — approximate HBM traffic assuming XLA fuses elementwise
    chains: bytes are charged at materialization points (dot operands +
    results, gathers/scatters, scan xs/ys streaming, reduce outputs)

Used by the dry-run to derive the §Roofline compute/memory terms; the raw
``compiled.cost_analysis()`` numbers are reported alongside for reference.
"""
from __future__ import annotations

import jax
import numpy as np
from jax import core as jcore


def _size_bytes(aval) -> int:
    if not hasattr(aval, "shape"):
        return 0
    return int(np.prod(aval.shape, dtype=np.int64) * aval.dtype.itemsize) \
        if aval.shape else aval.dtype.itemsize


def _numel(aval) -> int:
    return int(np.prod(aval.shape, dtype=np.int64)) if aval.shape else 1


_MATERIALIZING = {
    "dot_general", "gather", "scatter", "scatter-add", "scatter_add",
    "dynamic_slice", "dynamic_update_slice", "conv_general_dilated",
    "sort", "top_k", "cumsum", "cumlogsumexp", "argmax", "argmin",
}
_FREE = {"broadcast_in_dim", "reshape", "squeeze", "transpose", "convert_element_type",
         "slice", "concatenate", "iota", "copy", "stop_gradient", "pad"}


def count_jaxpr(jaxpr, scale: float = 1.0):
    """Returns (flops, hbm_bytes) for one jaxpr body, scaled."""
    flops = 0.0
    bytes_ = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                    "custom_vjp_call", "custom_vjp_call_jaxpr", "remat",
                    "remat2", "checkpoint", "custom_lin"):
            inner = None
            for k in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                if k in eqn.params:
                    inner = eqn.params[k]
                    break
            if inner is not None:
                ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                f, b = count_jaxpr(ij, scale)
                flops += f
                bytes_ += b
            continue
        if prim == "scan":
            length = eqn.params["length"]
            inner = eqn.params["jaxpr"].jaxpr
            f, b = count_jaxpr(inner, scale)
            flops += f * length
            bytes_ += b * length
            continue
        if prim == "while":
            inner = eqn.params["body_jaxpr"].jaxpr
            f, b = count_jaxpr(inner, scale)
            flops += f
            bytes_ += b
            continue
        if prim == "cond":
            branches = eqn.params["branches"]
            fb = [count_jaxpr(br.jaxpr, scale) for br in branches]
            f, b = max(fb)
            flops += f
            bytes_ += b
            continue
        out_elems = sum(_numel(v.aval) for v in eqn.outvars)
        if prim == "dot_general":
            dims = eqn.params["dimension_numbers"]
            (lc, rc), _ = dims
            lhs = eqn.invars[0].aval
            k = int(np.prod([lhs.shape[i] for i in lc], dtype=np.int64)) or 1
            flops += 2.0 * out_elems * k * scale
            bytes_ += (sum(_size_bytes(v.aval) for v in eqn.invars)
                       + sum(_size_bytes(v.aval) for v in eqn.outvars)) * scale
            continue
        if prim in _MATERIALIZING:
            bytes_ += (sum(_size_bytes(v.aval) for v in eqn.invars)
                       + sum(_size_bytes(v.aval) for v in eqn.outvars)) * scale
            flops += out_elems * scale
            continue
        if prim in _FREE:
            continue
        # elementwise / reductions: 1 flop per output element, fused bytes
        flops += out_elems * scale
    return flops, bytes_


def count_fn(fn, *args, **kwargs):
    """Counts (flops, hbm_bytes) of fn at the given abstract inputs,
    plus one read of all inputs and one write of all outputs."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    flops, bytes_ = count_jaxpr(closed.jaxpr)
    io = sum(_size_bytes(v.aval) for v in closed.jaxpr.invars)
    io += sum(_size_bytes(v.aval) for v in closed.jaxpr.outvars)
    return flops, bytes_ + io
