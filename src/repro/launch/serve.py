"""Serving drivers.

Two modes share this entrypoint:

  * default — SLA-aware SplitPlace plan selection over batched model
    requests (reduced model on CPU; mesh-slice plans on TPU):

        PYTHONPATH=src python -m repro.launch.serve --requests 20

  * ``--stream`` — the always-on edge-simulator serving loop
    (``repro.env.jaxsim.stream``): a host feeder thread streams Poisson
    task arrivals into the fixed-capacity device slot ring while the
    jitted interval program executes double-buffered chunks, printing
    rolling QPS / p50-p99 response / deadline-violation metrics:

        PYTHONPATH=src python -m repro.launch.serve --stream \\
            --policy mc --tasks 100000 --chunk 64
"""
from __future__ import annotations

import argparse

import numpy as np


def _stream_main(args):
    from repro.launch import experiments

    pretrain_state = None
    if args.pretrain > 0:
        print(f"pretraining ({args.pretrain} intervals)...")
        wants = ("splitplace",) if args.policy != "gillis" else ("gillis",)
        pretrain_state = experiments.pretrain(args.pretrain, lam=args.lam,
                                              policies=wants)

    def progress(i, runner, rolling):
        if i % args.report_every:
            return
        s = rolling.snapshot()
        print(f"chunk {i:5d}  intervals={runner.t0:7d}  "
              f"qps={s['qps']:.4f}/s  p50={s.get('p50_response_s', 0):.0f}s "
              f"p99={s.get('p99_response_s', 0):.0f}s  "
              f"viol={s['violation_rate']:.3f}  "
              f"occ={s['occupancy_mean']:.1f}", flush=True)

    rep = experiments.run_stream(
        policy=args.policy, lam=args.lam, seed=args.seed,
        target_tasks=args.tasks, chunk_intervals=args.chunk,
        max_active=args.capacity, interval_s=args.interval,
        substeps=args.substeps, window_intervals=args.window,
        pretrain_state=pretrain_state, on_chunk=progress)
    s = rep["summary"]
    print(f"\nserved {rep['finished']} tasks over {rep['n_intervals']} "
          f"intervals ({rep['n_chunks']} chunks of {args.chunk}); "
          f"{rep['live']} still live")
    print(f"admission: offered={rep['offered']} "
          f"feeder_overflow={rep['feeder_overflow']} "
          f"ring_dropped={rep['dropped']}")
    print(f"occupancy: max={rep['max_occupancy']:.0f}/{args.capacity}, "
          f"halves {rep['occupancy_mean_first_half']:.1f} / "
          f"{rep['occupancy_mean_second_half']:.1f}")
    print(f"summary: reward={s['reward']:.3f} "
          f"sla_violations={s['sla_violations']:.3f} "
          f"accuracy={s['accuracy']:.3f} "
          f"energy_mwhr={s['energy_mwhr']:.3f}")


def _plan_main(args):
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.engine import Request, SplitPlaceEngine

    cfg = get_config(args.arch).reduced(max_d_model=256, max_layers=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = SplitPlaceEngine(params, cfg, num_stages=args.stages,
                           num_branches=args.branches)
    rng = np.random.RandomState(0)
    tok = rng.randint(0, cfg.vocab_size,
                      (args.batch, args.seq)).astype(np.int32)
    eng.warmup(tok)
    _, t_layer = eng._run(0, {"tokens": jax.numpy.asarray(tok)})
    _, t_sem = eng._run(1, {"tokens": jax.numpy.asarray(tok)})
    print(f"plan latencies: layer-pipeline {t_layer*1e3:.1f}ms, "
          f"semantic-branch {t_sem*1e3:.1f}ms")
    for i in range(args.requests):
        tight = rng.rand() < 0.5
        ddl = t_sem * 2.5 if tight else t_layer * 4.0
        r = eng.serve(Request(tokens=tok, deadline_s=float(ddl)))
        print(f"req {i:3d} deadline={'tight' if tight else 'loose'} -> "
              f"plan={'layer' if r.plan == 0 else 'semantic'} "
              f"lat={r.latency_s*1e3:.1f}ms fid={r.fidelity:.3f} "
              f"met={r.met_deadline} reward={r.reward:.3f}")
    print(f"final MAB Q:\n{np.asarray(eng.state.Q).round(3)}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--branches", type=int, default=2)
    ap.add_argument("--stream", action="store_true",
                    help="run the always-on edge-sim serving loop "
                         "instead of model-plan selection")
    ap.add_argument("--policy", default="mc",
                    help="stream mode: policy name (static BestFit or "
                         "mab/splitplace/mab+gobi/gillis)")
    ap.add_argument("--lam", type=float, default=6.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tasks", type=int, default=10_000,
                    help="stream mode: stop after offering this many")
    ap.add_argument("--chunk", type=int, default=64,
                    help="stream mode: intervals per jitted chunk")
    ap.add_argument("--capacity", type=int, default=512,
                    help="stream mode: device ring slot capacity")
    ap.add_argument("--interval", type=float, default=300.0)
    ap.add_argument("--substeps", type=int, default=30)
    ap.add_argument("--window", type=int, default=256,
                    help="stream mode: rolling-metrics window intervals")
    ap.add_argument("--report-every", type=int, default=10,
                    help="stream mode: print rolling metrics every N "
                         "chunks")
    ap.add_argument("--pretrain", type=int, default=0,
                    help="stream mode: §6.3 pretraining intervals for "
                         "learned policies (0 = cold start)")
    args = ap.parse_args(argv)
    if args.stream:
        _stream_main(args)
    else:
        _plan_main(args)


if __name__ == "__main__":
    main()
