"""Serving driver: SLA-aware SplitPlace plan selection over batched
requests (reduced model on CPU; mesh-slice plans on TPU).

    PYTHONPATH=src python -m repro.launch.serve --requests 20
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Request, SplitPlaceEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--branches", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced(max_d_model=256, max_layers=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = SplitPlaceEngine(params, cfg, num_stages=args.stages,
                           num_branches=args.branches)
    rng = np.random.RandomState(0)
    tok = rng.randint(0, cfg.vocab_size,
                      (args.batch, args.seq)).astype(np.int32)
    eng.warmup(tok)
    _, t_layer = eng._run(0, {"tokens": jax.numpy.asarray(tok)})
    _, t_sem = eng._run(1, {"tokens": jax.numpy.asarray(tok)})
    print(f"plan latencies: layer-pipeline {t_layer*1e3:.1f}ms, "
          f"semantic-branch {t_sem*1e3:.1f}ms")
    for i in range(args.requests):
        tight = rng.rand() < 0.5
        ddl = t_sem * 2.5 if tight else t_layer * 4.0
        r = eng.serve(Request(tokens=tok, deadline_s=float(ddl)))
        print(f"req {i:3d} deadline={'tight' if tight else 'loose'} -> "
              f"plan={'layer' if r.plan == 0 else 'semantic'} "
              f"lat={r.latency_s*1e3:.1f}ms fid={r.fidelity:.3f} "
              f"met={r.met_deadline} reward={r.reward:.3f}")
    print(f"final MAB Q:\n{np.asarray(eng.state.Q).round(3)}")


if __name__ == "__main__":
    main()
