"""Batched experiment runner — (policy × seed × λ) grids over the edge sim.

The paper's headline results need hundreds of interval traces (Table 4:
7 policies × seeds × Γ=100 intervals on top of 200 MAB-pretraining
intervals; §6.4/A.3-A.5 sweeps more).  This module owns the canonical
interval loop (``run_trace``, Algorithm 1) and a grid driver
(``run_grid``) so every benchmark shares:

  * one MAB pretraining trace (§6.3) and one Gillis Q-pretraining trace
    per grid, instead of per-call copies;
  * the process-wide DASO jit cache — ``SurrogatePlacer`` training is
    shape-stable (fixed 64-row replay window, see
    ``daso.train_epoch_weighted``), so every surrogate policy in the grid
    reuses the same compiled ``optimize_placement`` / ``train_epoch``
    executables rather than re-tracing per instance;
  * two simulator backends: ``backend="soa"`` — the vectorized NumPy
    ``EdgeSim`` host loop (the §6.3 pretraining substrate and the
    object-level reference for every policy) — and ``backend="jax"`` —
    the fixed-capacity jitted simulator (``repro.env.jaxsim``), where
    ``run_grid_batched`` runs a whole (seed × λ) grid as one compiled
    vmapped call: static BestFit policies plus the in-kernel learned
    engines ``"mab"`` / ``"splitplace"`` (online UCB/ε-greedy MAB,
    Algorithm-1 feedback and the array-form DASO placer inside the
    kernel, deploying — or in ``mode="train"`` finetuning — the states
    ``pretrain`` produced), the decision-blind ``"mab+gobi"`` ablation,
    and the ``"gillis"`` contextual Q-learning baseline.

``repro.core.splitplace.run_experiment`` and the Table 4 / sensitivity
benchmarks are thin wrappers over these entry points.
"""
from __future__ import annotations

import itertools
from typing import (Callable, Dict, Iterable, List, NamedTuple, Optional,
                    Sequence)

import numpy as np

from repro.core import splitplace as sp
from repro.core.policies import Policy
from repro.env.cluster import FLEET_SPEC, make_cluster
from repro.env.metrics import TELEMETRY_COLS, MetricsAccumulator
from repro.env.simulator import EdgeSim

#: policies whose decider consumes a pretrained MAB state
MAB_STATE_POLICIES = ("splitplace", "mab+gobi", "mab")


class PretrainState(NamedTuple):
    """Everything the §6.3 pretraining pass produces.

    ``mab_state`` seeds both the host deciders and the in-kernel carried
    MAB; ``daso_theta``/``daso_cfg`` are the trained placement surrogate
    the jitted backend's array-form DASO stage consumes
    (``run_grid_batched(policy="splitplace", ...)``);
    ``daso_opt_state`` is the AdamW moment state the pretraining pass
    ended on, so ``mode="train"`` grids continue finetuning in-kernel
    from the exact pretrain optimizer trajectory; ``gillis_policy`` is
    the continued Gillis baseline object (host backend only).  Fields
    are ``None`` when the requested policy set doesn't need them.
    """
    mab_state: Optional[object] = None
    gillis_policy: Optional[object] = None
    daso_theta: Optional[object] = None
    daso_cfg: Optional[object] = None
    daso_opt_state: Optional[object] = None


def run_trace(policy_name: Optional[str] = None, n_intervals: int = 100,
              lam: float = 6.0, seed: int = 0, mab_state=None,
              train: bool = False, cluster=None, apps=None,
              interval_s: float = 300.0, substeps: int = 30,
              policy: Optional[Policy] = None,
              backend: str = "soa", daso_theta=None, daso_cfg=None,
              daso_opt_state=None, mode: str = "deploy",
              substep_impl: Optional[str] = None,
              telemetry: str = "summary") -> dict:
    """Run one execution trace; returns the §6.4 metric summary.

    Pass ``policy`` to continue a pre-trained policy object (used to
    pretrain the Gillis baseline's Q-learner, mirroring the MAB's
    pretraining phase).  ``backend="jax"`` compiles the workload and runs
    the jitted fixed-capacity simulator — static BestFit policies, plus
    the in-kernel learned engines: ``"mab"`` (online MAB + BestFit),
    ``"splitplace"`` (online MAB + array-form DASO; needs
    ``daso_theta``/``daso_cfg`` from ``pretrain``), ``"mab+gobi"``
    (same surrogate machinery, decision-blind input) and ``"gillis"``
    (contextual ε-greedy Q-learning, always online — ``mode`` is
    ignored for it).  ``mode`` selects
    the learned policies' in-kernel loop: ``"deploy"`` (UCB decisions,
    frozen surrogate) or ``"train"`` (ε-greedy decisions + in-kernel
    DASO finetuning; pass ``daso_opt_state`` to continue the pretrain
    optimizer trajectory).  On the host backend ``mode="train"`` is the
    ε-greedy training flag (same as ``train=True``).  The static-decider
    surrogate arms (``jaxsim.STATIC_DASO_ARMS``: ``"semantic+gobi"``,
    ``"layer+gobi"``, ``"random+daso"``) also run in-kernel on
    ``backend="jax"`` — pass ``daso_theta``/``daso_cfg`` from
    ``pretrain()``.  ``substep_impl`` selects the jitted backend's
    substep physics implementation (``"xla"``/``"pallas"``/``"ref"``;
    None → env/default).  ``telemetry="interval"`` records the
    per-interval telemetry series on either backend and adds response/
    wait percentiles to the summary (exact on the host; binned with a
    reported error bound on the jitted backend)."""
    if mode not in ("deploy", "train"):
        raise ValueError(f"unknown mode {mode!r}")
    if backend == "jax":
        if policy is not None or train:
            raise ValueError("backend='jax' takes policy names only "
                             "(no policy objects; ε-greedy training is "
                             "mode='train' on the learned policies)")
        from repro.env import jaxsim
        if policy_name == "gillis":
            # the Gillis baseline's ε-greedy Q-loop is inherently online
            # (mode is moot); its dual traces realize layer vs compressed
            from repro.env.workload import COMPRESSED, LAYER
            tr = jaxsim.compile_trace_dual(
                lam=lam, seed=seed, n_intervals=n_intervals,
                interval_s=interval_s, substeps=substeps, apps=apps,
                cluster=cluster, variants=(LAYER, COMPRESSED))
            out = jaxsim.run_trace_arrays_gillis(tr, cluster=cluster,
                                                 substep_impl=substep_impl,
                                                 telemetry=telemetry)
            out["policy"] = policy_name
            return out
        if policy_name in jaxsim.LEARNED_POLICIES:
            if mab_state is None:
                raise ValueError(f"policy {policy_name!r} needs a "
                                 "pretrained mab_state (see pretrain())")
            if policy_name in jaxsim.DASO_LEARNED_POLICIES and \
                    (daso_theta is None or daso_cfg is None):
                raise ValueError(f"policy {policy_name!r} needs daso_theta/"
                                 "daso_cfg (see pretrain())")
            tr = jaxsim.compile_trace_dual(
                lam=lam, seed=seed, n_intervals=n_intervals,
                interval_s=interval_s, substeps=substeps, apps=apps,
                cluster=cluster)
            use_daso = policy_name in jaxsim.DASO_LEARNED_POLICIES
            # mab+gobi = identical surrogate machinery, decision one-hot
            # masked out of the surrogate input (the paper's
            # decision-blind GOBI ablation)
            cfg = daso_cfg._replace(decision_aware=False) \
                if policy_name == "mab+gobi" else daso_cfg
            if mode == "train":
                out = jaxsim.run_trace_arrays_trained(
                    tr, mab_state, cluster=cluster,
                    daso_theta=daso_theta if use_daso else None,
                    daso_cfg=cfg if use_daso else None,
                    daso_opt_state=daso_opt_state if use_daso else None,
                    substep_impl=substep_impl, telemetry=telemetry)
            else:
                out = jaxsim.run_trace_arrays_learned(
                    tr, mab_state, cluster=cluster,
                    daso_theta=daso_theta if use_daso else None,
                    daso_cfg=cfg if use_daso else None,
                    substep_impl=substep_impl, telemetry=telemetry)
            out["policy"] = policy_name
            return out
        if mode == "train":
            raise ValueError(f"policy {policy_name!r} is static — "
                             "mode='train' needs a learned policy "
                             f"({jaxsim.LEARNED_POLICIES})")
        if policy_name in jaxsim.STATIC_DASO_ARMS:
            # static decider + frozen surrogate placer, fully in-kernel
            if daso_theta is None or daso_cfg is None:
                raise ValueError(f"policy {policy_name!r} needs daso_theta/"
                                 "daso_cfg (see pretrain())")
            tr = jaxsim.compile_trace_dual(
                lam=lam, seed=seed, n_intervals=n_intervals,
                interval_s=interval_s, substeps=substeps, apps=apps,
                cluster=cluster)
            out = jaxsim.run_trace_arrays_static_daso(
                tr, policy_name, daso_theta=daso_theta, daso_cfg=daso_cfg,
                cluster=cluster, substep_impl=substep_impl,
                telemetry=telemetry)
            out["policy"] = policy_name
            return out
        dec = jaxsim.make_static_decider(policy_name, mab_state=mab_state,
                                         seed=seed)
        tr = jaxsim.compile_trace(dec, lam=lam, seed=seed,
                                  n_intervals=n_intervals,
                                  interval_s=interval_s, substeps=substeps,
                                  apps=apps, cluster=cluster)
        out = jaxsim.run_trace_arrays(tr, cluster=cluster,
                                      substep_impl=substep_impl,
                                      telemetry=telemetry)
        out["policy"] = policy_name
        return out
    if backend != "soa":
        raise ValueError(f"unknown backend {backend!r}")
    if telemetry not in ("summary", "interval"):
        raise ValueError(f"telemetry={telemetry!r} "
                         "(want 'summary' or 'interval')")
    tel = telemetry == "interval"
    train = train or mode == "train"
    sim = EdgeSim(cluster=cluster, lam=lam, seed=seed, apps=apps,
                  interval_s=interval_s, substeps=substeps)
    policy = policy or sp.make_policy(policy_name, sim.cluster.n, seed=seed,
                                      mab_state=mab_state, train=train)
    acc = MetricsAccumulator(interval_s=interval_s, telemetry=tel)
    for _ in range(n_intervals):
        tasks = sim.new_interval_tasks()
        decisions = policy.decider.decide(tasks)
        sim.admit(tasks, decisions)
        assignment = policy.placer.place(sim)
        sim.apply_placement(assignment)
        stats = sim.advance()
        policy.decider.feedback(stats.finished)
        if isinstance(policy.placer, sp.SurrogatePlacer):
            o_mab = (policy.decider.interval_reward(stats.finished)
                     if isinstance(policy.decider, sp.MABDecider)
                     else sp.MABDecider().interval_reward(stats.finished))
            policy.placer.feedback(o_mab, stats, sim)
        acc.update(stats)
    out = acc.summary()
    if tel:
        # object-loop policies have no kernel engine, so the series
        # carries the base columns only; percentiles are exact
        out.update(acc.percentiles())
        out["percentile_err_s"] = 0.0
        out["telemetry"] = {"cols": list(TELEMETRY_COLS),
                            "series": acc.telemetry_series()}
    out["policy"] = policy.name
    out["policy_obj"] = policy
    if isinstance(policy.decider, sp.MABDecider):
        out["mab_state"] = policy.decider.state
    return out


def pretrain(n_intervals: int, lam: float = 6.0, seed: int = 7,
             substeps: int = 30, interval_s: float = 300.0,
             policies: Sequence[str] = ("splitplace",)) -> PretrainState:
    """§6.3 pretraining pass: feedback-based ε-greedy MAB training with
    DASO online finetuning (and, when 'gillis' is requested, the Gillis
    Q-learner on the same budget).  Returns a ``PretrainState`` whose
    fields are None when not requested.

    The training trace runs on the host backend (ε-greedy exploration and
    surrogate finetuning are inherently sequential); the resulting
    ``mab_state`` and DASO ``theta`` then flow into either backend —
    host deciders/placers or the jitted in-kernel learned policies."""
    out = PretrainState()
    if any(p in MAB_STATE_POLICIES for p in policies):
        r = run_trace("splitplace", n_intervals=n_intervals, lam=lam,
                      seed=seed, train=True, substeps=substeps,
                      interval_s=interval_s)
        placer = r["policy_obj"].placer
        out = out._replace(mab_state=r["mab_state"],
                           daso_theta=placer.theta, daso_cfg=placer.cfg,
                           daso_opt_state=placer.opt_state)
    if "gillis" in policies:
        r = run_trace("gillis", n_intervals=n_intervals, lam=lam, seed=seed,
                      substeps=substeps, interval_s=interval_s)
        out = out._replace(gillis_policy=r["policy_obj"])
    return out


_SCALARS = (int, float)


def _record(pol: str, seed: int, lam: float, summary: dict) -> dict:
    rec = {"policy": pol, "seed": seed, "lam": lam}
    rec.update({k: float(v) for k, v in summary.items()
                if isinstance(v, _SCALARS) and not isinstance(v, bool)})
    return rec


def run_grid_batched(policy: str = "mc", seeds: Sequence[int] = (0,),
                     lams: Sequence[float] = (6.0,), n_intervals: int = 100,
                     substeps: int = 30, interval_s: float = 300.0,
                     apps=None, cluster=None, mab_state=None, seed_offset=0,
                     max_active: Optional[int] = None,
                     threads: Optional[int] = None,
                     pretrain_state: Optional[PretrainState] = None,
                     daso_theta=None, daso_cfg=None, daso_opt_state=None,
                     gillis_state=None, mab_hp=None, train_hp=None,
                     mode: str = "deploy", devices=None,
                     substep_impl: Optional[str] = None,
                     telemetry: str = "summary") -> List[dict]:
    """Run a whole (seed × λ) grid for one policy as ONE compiled vmapped
    call on the jitted backend; one record per trace, in
    ``itertools.product(lams, seeds)`` order (matching ``run_grid``).

    Besides the static BestFit policies, every in-kernel learned policy
    (``jaxsim.LEARNED_POLICIES``) is accepted — each is an engine over
    the unified interval program, carrying its state through the jitted
    carry with online decisions and per-interval feedback inside the
    kernel, one state copy per grid cell:

      * ``"mab"`` / ``"splitplace"`` — the pretrained ``MABState``
        (plus, for splitplace, the DASO surrogate theta);
      * ``"mab+gobi"`` — the decision-blind GOBI ablation: identical
        surrogate machinery with the decision one-hot masked out of the
        surrogate input (Table 4's M+G row);
      * ``"gillis"`` — the Gillis baseline's contextual ε-greedy
        Q-learner (layer vs compressed) — no pretraining products
        needed; pass ``gillis_state={"Q":..., "eps":...}`` to continue
        one (records keep only scalar metrics, so obtain the Q-table to
        continue from by calling ``jaxsim.run_grid_arrays_gillis``
        directly — its summaries carry ``"gillis_q"``).  Its Q-loop is
        inherently online, so ``mode`` is ignored.

    ``mode="train"`` switches the MAB policies to the full §6.3
    in-kernel training loop: ε-greedy decisions (eq. 6) and, for the
    surrogate placers, online DASO finetuning (replay-window appends +
    ``train_epoch_weighted`` steps in the carry).  ``mab_hp`` /
    ``train_hp`` override the driver defaults (the α×λ sensitivity
    sweep drives eq. 10's α/β through ``train_hp``).  Pass the
    pretraining products either as ``pretrain_state`` (the
    ``pretrain()`` result) or as the individual ``mab_state``/
    ``daso_theta``/``daso_cfg``/``daso_opt_state`` fields.

    The static-decider surrogate arms (``jaxsim.STATIC_DASO_ARMS``:
    ``"semantic+gobi"``, ``"layer+gobi"``, ``"random+daso"``) run as one
    dual-trace engine — a fixed (or fold-in-random) split decision with
    the frozen DASO surrogate placer in-kernel; they need
    ``daso_theta``/``daso_cfg`` like ``"splitplace"`` but no
    ``mab_state``.

    ``devices`` routes the grid through the shard_map dispatcher (1-D
    ``"grid"`` device mesh; ``"auto"`` = every visible device) instead of
    the host thread-chunk pool; ``substep_impl`` selects the substep
    physics implementation (``"xla"``/``"pallas"``/``"ref"``, None →
    ``JAXSIM_SUBSTEP_IMPL`` env or ``"xla"``).

    ``telemetry="interval"`` threads the driver's per-interval telemetry
    knob through every arm; records keep only the scalar percentile
    fields (``_record`` drops the non-scalar series payload) — call the
    ``jaxsim.run_grid_arrays*`` functions directly for the full series.

    Workload compilation is host-side and cheap; the interval dynamics
    (decisions + placement + substep physics + metric accumulators) run
    batched, so every sequential greedy placement iteration is shared by
    all grid cells.  See ``repro.env.jaxsim`` for the capacity/padding
    contract — records report ``dropped_tasks`` (0 unless ``max_active``
    was forced too small)."""
    from repro.env import jaxsim
    if mode not in ("deploy", "train"):
        raise ValueError(f"unknown mode {mode!r}")
    if pretrain_state is not None:
        mab_state = mab_state if mab_state is not None \
            else pretrain_state.mab_state
        daso_theta = daso_theta if daso_theta is not None \
            else pretrain_state.daso_theta
        daso_cfg = daso_cfg if daso_cfg is not None \
            else pretrain_state.daso_cfg
        daso_opt_state = daso_opt_state if daso_opt_state is not None \
            else pretrain_state.daso_opt_state
    cells = list(itertools.product(lams, seeds))
    if policy == "gillis":
        from repro.env.workload import COMPRESSED, LAYER
        traces = [jaxsim.compile_trace_dual(
            lam=lam, seed=seed + seed_offset, n_intervals=n_intervals,
            interval_s=interval_s, substeps=substeps, apps=apps,
            cluster=cluster, variants=(LAYER, COMPRESSED))
            for lam, seed in cells]
        kw = {} if gillis_state is None else {"gillis_state": gillis_state}
        outs = jaxsim.run_grid_arrays_gillis(
            traces, cluster=cluster, max_active=max_active,
            threads=threads, devices=devices, substep_impl=substep_impl,
            telemetry=telemetry, **kw)
        return [_record(policy, seed, lam, out)
                for (lam, seed), out in zip(cells, outs)]
    if policy in jaxsim.STATIC_DASO_ARMS:
        if daso_theta is None or daso_cfg is None:
            raise ValueError(f"policy {policy!r} needs daso_theta/"
                             "daso_cfg (see pretrain())")
        traces = [jaxsim.compile_trace_dual(
            lam=lam, seed=seed + seed_offset, n_intervals=n_intervals,
            interval_s=interval_s, substeps=substeps, apps=apps,
            cluster=cluster) for lam, seed in cells]
        outs = jaxsim.run_grid_arrays_static_daso(
            traces, policy, daso_theta=daso_theta, daso_cfg=daso_cfg,
            cluster=cluster, max_active=max_active, threads=threads,
            devices=devices, substep_impl=substep_impl,
            telemetry=telemetry)
        return [_record(policy, seed, lam, out)
                for (lam, seed), out in zip(cells, outs)]
    if policy in jaxsim.LEARNED_POLICIES:
        if mab_state is None:
            raise ValueError(f"policy {policy!r} needs a pretrained "
                             "mab_state (see pretrain())")
        if policy in jaxsim.DASO_LEARNED_POLICIES and \
                (daso_theta is None or daso_cfg is None):
            raise ValueError(f"policy {policy!r} needs daso_theta/"
                             "daso_cfg (see pretrain())")
        traces = [jaxsim.compile_trace_dual(
            lam=lam, seed=seed + seed_offset, n_intervals=n_intervals,
            interval_s=interval_s, substeps=substeps, apps=apps,
            cluster=cluster) for lam, seed in cells]
        use_daso = policy in jaxsim.DASO_LEARNED_POLICIES
        cfg = daso_cfg._replace(decision_aware=False) \
            if policy == "mab+gobi" else daso_cfg
        hp_kw = {} if mab_hp is None else {"mab_hp": tuple(mab_hp)}
        if mode == "train":
            if train_hp is not None:
                hp_kw["train_hp"] = tuple(train_hp)
            outs = jaxsim.run_grid_arrays_trained(
                traces, mab_state, cluster=cluster, max_active=max_active,
                threads=threads, devices=devices,
                substep_impl=substep_impl, telemetry=telemetry,
                daso_theta=daso_theta if use_daso else None,
                daso_cfg=cfg if use_daso else None,
                daso_opt_state=daso_opt_state if use_daso else None,
                **hp_kw)
        else:
            outs = jaxsim.run_grid_arrays_learned(
                traces, mab_state, cluster=cluster, max_active=max_active,
                threads=threads, devices=devices,
                substep_impl=substep_impl, telemetry=telemetry,
                daso_theta=daso_theta if use_daso else None,
                daso_cfg=cfg if use_daso else None, **hp_kw)
        return [_record(policy, seed, lam, out)
                for (lam, seed), out in zip(cells, outs)]
    if mode == "train":
        raise ValueError(f"policy {policy!r} is static — mode='train' "
                         f"needs a learned policy "
                         f"({jaxsim.LEARNED_POLICIES})")
    dec = jaxsim.make_static_decider(policy, mab_state=mab_state)
    traces = [jaxsim.compile_trace(dec, lam=lam, seed=seed + seed_offset,
                                   n_intervals=n_intervals,
                                   interval_s=interval_s, substeps=substeps,
                                   apps=apps, cluster=cluster)
              for lam, seed in cells]
    outs = jaxsim.run_grid_arrays(traces, cluster=cluster,
                                  max_active=max_active, threads=threads,
                                  devices=devices,
                                  substep_impl=substep_impl,
                                  telemetry=telemetry)
    return [_record(policy, seed, lam, out)
            for (lam, seed), out in zip(cells, outs)]


def run_stream(policy: str = "mc", lam: float = 6.0, seed: int = 0,
               target_tasks: int = 10_000, chunk_intervals: int = 64,
               max_active: int = 512, interval_s: float = 300.0,
               substeps: int = 30, window_intervals: int = 256,
               apps=None, cluster=None,
               pretrain_state: Optional[PretrainState] = None,
               mab_state=None, daso_theta=None, daso_cfg=None,
               gillis_state=None, max_arrivals: Optional[int] = None,
               prefetch: int = 2, substep_impl: Optional[str] = None,
               on_chunk: Optional[Callable] = None) -> dict:
    """Always-on serving run: stream Poisson arrivals through the
    chunked jitted interval program until ``target_tasks`` tasks have
    been offered (``repro.env.jaxsim.stream.serve``); a host feeder
    thread fills the next chunk's arrival tape while the device executes
    the current one.

    Accepts the same policy names and pretraining products as
    ``run_grid_batched`` (static BestFit policies run a host decider
    feeder; ``"mab"``/``"splitplace"``/``"mab+gobi"``/``"gillis"``
    serve their in-kernel engines, continuing ``pretrain_state`` when
    given and cold-starting otherwise).  Returns the serving report —
    admission ledger, ring occupancy, rolling-window QPS / percentile /
    violation metrics, and the cumulative §6.4 summary — annotated with
    the grid coordinates."""
    from repro.env.jaxsim import stream
    cluster = cluster or make_cluster()
    if pretrain_state is not None:
        mab_state = mab_state if mab_state is not None \
            else pretrain_state.mab_state
        daso_theta = daso_theta if daso_theta is not None \
            else pretrain_state.daso_theta
        daso_cfg = daso_cfg if daso_cfg is not None \
            else pretrain_state.daso_cfg
    engine, es0, feeder_kw = stream.make_stream_policy(
        policy, cluster=cluster, seed=seed, mab_state=mab_state,
        daso_theta=daso_theta, daso_cfg=daso_cfg,
        gillis_state=gillis_state)
    feeder = stream.StreamFeeder(lam=lam, seed=seed, interval_s=interval_s,
                                 substeps=substeps, cluster=cluster,
                                 apps=apps, max_arrivals=max_arrivals,
                                 **feeder_kw)
    rep = stream.serve(engine, es0, feeder, chunk_intervals=chunk_intervals,
                       max_active=max_active, target_tasks=target_tasks,
                       window_intervals=window_intervals, prefetch=prefetch,
                       substep_impl=substep_impl, on_chunk=on_chunk)
    rep.update(policy=policy, lam=lam, seed=seed)
    return rep


def run_grid(policies: Sequence[str], seeds: Sequence[int] = (0,),
             lams: Sequence[float] = (6.0,), n_intervals: int = 100,
             substeps: int = 30, interval_s: float = 300.0, apps=None,
             cluster_factory: Optional[Callable[[], object]] = None,
             pretrain_intervals: int = 0, pretrain_lam: Optional[float] = None,
             pretrain_seed: int = 7, mab_state=None, gillis_policy=None,
             progress: Optional[Callable[[str], None]] = None,
             backend: str = "soa", daso_theta=None,
             daso_cfg=None, daso_opt_state=None,
             mode: str = "deploy") -> List[dict]:
    """Run the full (λ × policy × seed) grid; one record per trace.

    ``pretrain_intervals > 0`` runs the shared §6.3 pretraining pass once
    for the whole grid (skipped for strategies that don't consume it).
    The Gillis policy object is continued across its grid cells, matching
    the sequential-evaluation protocol of the seed benchmarks.  A fresh
    cluster comes from ``cluster_factory`` per trace (default: the Table 3
    50-worker fleet).

    ``backend="jax"`` routes every policy through ``run_grid_batched`` —
    one compiled call per policy instead of a Python loop per cell;
    record order matches the host backend.  Static BestFit policies and
    the in-kernel learned policies ("mab"/"splitplace") are both
    accepted; the pretraining pass (host-side, shared) runs when a
    learned policy needs states that weren't passed in.  ``mode="train"``
    selects the in-kernel §6.3 training loop for the learned policies on
    the jitted backend (ε-greedy decisions + DASO finetuning in the
    carry) and the host training flag on ``backend="soa"``."""
    if mode not in ("deploy", "train"):
        raise ValueError(f"unknown mode {mode!r}")
    if backend == "jax":
        from repro.env.jaxsim import (DASO_LEARNED_POLICIES,
                                      LEARNED_POLICIES,
                                      MAB_LEARNED_POLICIES,
                                      STATIC_DASO_ARMS)
        # pretrain only for what the requested policies actually consume:
        # the MAB-family learned policies need mab_state, the surrogate
        # placers (splitplace / mab+gobi) need the DASO products, and
        # the in-kernel Gillis baseline needs nothing (fresh Q/ε per
        # grid).  The pass is a full host-loop trace — the most
        # expensive step in the pipeline.
        needs_mab = any(p in MAB_LEARNED_POLICIES for p in policies) \
            and mab_state is None
        needs_daso = any(p in DASO_LEARNED_POLICIES
                         or p in STATIC_DASO_ARMS for p in policies) \
            and daso_theta is None
        if pretrain_intervals and (needs_mab or needs_daso):
            pre = pretrain(pretrain_intervals,
                           lam=pretrain_lam if pretrain_lam is not None
                           else lams[0],
                           seed=pretrain_seed, substeps=substeps,
                           interval_s=interval_s)
            mab_state = mab_state if mab_state is not None \
                else pre.mab_state
            daso_theta = daso_theta if daso_theta is not None \
                else pre.daso_theta
            daso_cfg = daso_cfg if daso_cfg is not None else pre.daso_cfg
            daso_opt_state = daso_opt_state if daso_opt_state is not None \
                else pre.daso_opt_state
        records = []
        for pol in policies:
            # mab_state passes through untouched to static policies: only
            # the frozen-UCB decider ("bestfit-mab") consumes it there;
            # learned policies thread it through the kernel carry.  mode
            # only applies to learned policies — static ones have no
            # training loop, so a mixed list runs them in deploy form
            # (mirroring backend="soa", where train=True is a no-op for
            # policies without a learning decider)
            records += run_grid_batched(
                pol, seeds=seeds, lams=lams, n_intervals=n_intervals,
                substeps=substeps, interval_s=interval_s, apps=apps,
                cluster=cluster_factory() if cluster_factory else None,
                mab_state=mab_state, daso_theta=daso_theta,
                daso_cfg=daso_cfg, daso_opt_state=daso_opt_state,
                mode=mode if pol in LEARNED_POLICIES else "deploy")
        # run_grid order is (lam, policy, seed); per-policy batches are
        # (lam, seed) — reorder to match the host backend exactly
        by_cell = {(r["lam"], r["policy"], r["seed"]): r for r in records}
        records = [by_cell[(lam, pol, seed)]
                   for lam, pol, seed in itertools.product(lams, policies,
                                                           seeds)]
        if progress:
            for rec in records:
                progress(f"lam={rec['lam']:g} {rec['policy']:15s} "
                         f"seed={rec['seed']} reward={rec['reward']:.4f} "
                         f"viol={rec['sla_violations']:.2f}")
        return records
    if pretrain_intervals:
        pre = pretrain(pretrain_intervals,
                       lam=pretrain_lam if pretrain_lam is not None
                       else lams[0],
                       seed=pretrain_seed, substeps=substeps,
                       interval_s=interval_s,
                       policies=[p for p in policies
                                 if (p in MAB_STATE_POLICIES
                                     and mab_state is None)
                                 or (p == "gillis"
                                     and gillis_policy is None)])
        mab_state = mab_state if mab_state is not None else pre.mab_state
        gillis_policy = gillis_policy if gillis_policy is not None \
            else pre.gillis_policy
    records = []
    for lam, pol, seed in itertools.product(lams, policies, seeds):
        ms = mab_state if pol in MAB_STATE_POLICIES else None
        r = run_trace(pol, n_intervals=n_intervals, lam=lam, seed=seed,
                      mab_state=ms, train=mode == "train",
                      substeps=substeps,
                      interval_s=interval_s, apps=apps,
                      cluster=cluster_factory() if cluster_factory else None,
                      policy=gillis_policy if pol == "gillis" else None)
        records.append(_record(pol, seed, lam, r))
        if progress:
            rec = records[-1]
            progress(f"lam={lam:g} {pol:15s} seed={seed} "
                     f"reward={rec['reward']:.4f} "
                     f"viol={rec['sla_violations']:.2f}")
    return records


def aggregate(records: Iterable[dict],
              by: Sequence[str] = ("policy",)) -> Dict:
    """Group records and average every numeric metric; adds
    ``reward_std`` and ``n_runs``.  Keys are the ``by`` values (a scalar
    for a single key, else a tuple)."""
    groups: Dict = {}
    for rec in records:
        key = tuple(rec[k] for k in by)
        groups.setdefault(key[0] if len(by) == 1 else key, []).append(rec)
    out = {}
    # grid coordinates are labels, not metrics — never average them in
    skip = set(by) | {"policy", "seed", "lam"}
    for key, rs in groups.items():
        agg = {k: float(np.mean([r[k] for r in rs]))
               for k in rs[0] if k not in skip
               and isinstance(rs[0][k], _SCALARS)}
        agg["reward_std"] = float(np.std([r["reward"] for r in rs]))
        agg["n_runs"] = len(rs)
        out[key] = agg
    return out


def scaled_fleet(factor: int):
    """Scale the Table 3 fleet spec by an integer factor (2 → a
    100-worker cluster) — the SoA simulator makes these affordable."""
    return [(name, qty * factor) for name, qty in FLEET_SPEC]


def make_scaled_cluster(factor: int, **kw):
    return make_cluster(fleet=scaled_fleet(factor), **kw)
