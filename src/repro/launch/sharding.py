"""GSPMD sharding rules for all architectures on the production mesh.

Strategy (DESIGN.md §3):
  * batch              -> ('pod', 'data')           (pure DP over pods)
  * residual seq       -> 'model'                   (sequence parallelism)
  * heads / ffn hidden / experts / vocab -> 'model' (tensor / expert parallel)
  * params + optimizer state: FSDP over ('pod','data') on the largest
    non-TP dim, TP over 'model'                     (512-way for >=100B)

Divisibility-aware: a dim is sharded over an axis group only if it divides
evenly (e.g. musicgen's 24 heads and qwen2-vl's 28 heads skip head-TP and
keep MLP-TP + FSDP; the head-TP gap is a documented §Perf item).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes


def _axes_size(mesh, axes):
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def _fit(mesh, dim_size, axes):
    """axes if dim divides evenly else None."""
    if axes is None or dim_size <= 0:
        return None
    if dim_size % _axes_size(mesh, axes) == 0:
        return axes
    return None


def _path_str(path):
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def param_pspec(mesh, cfg, path, leaf) -> P:
    """PartitionSpec for one parameter leaf, by name and shape."""
    name = _path_str(path)
    shape = leaf.shape
    fsdp = batch_axes(mesh)
    tp = "model"
    nd = len(shape)
    # stacked scan body adds a leading periods dim
    lead = 1 if name.startswith("body/") and nd >= 1 else 0

    def spec(*dims):
        return P(*([None] * lead + list(dims) + [None] * (nd - lead - len(dims))))

    base = name.split("/")[-1]
    d = shape[lead:] if lead else shape

    if base in ("embed",):
        if cfg.num_codebooks:
            return spec(None, _fit(mesh, d[1], tp), _fit(mesh, d[2], fsdp))
        return spec(_fit(mesh, d[0], tp), _fit(mesh, d[1], fsdp))
    if base in ("head",):
        if cfg.num_codebooks:
            return spec(None, _fit(mesh, d[1], fsdp), _fit(mesh, d[2], tp))
        return spec(_fit(mesh, d[0], fsdp), _fit(mesh, d[1], tp))
    if nd - lead <= 1:  # norms, 1D biases, Lambda, D, dt_bias, conv_b
        return spec(_fit(mesh, d[0], tp) if base in ("Lambda", "D", "conv_b", "b_a", "b_i", "dt_bias") else None)

    if base in ("wq", "wk", "wv"):
        heads = d[1]
        if _fit(mesh, heads, tp):
            return spec(_fit(mesh, d[0], fsdp), tp, None)
        return spec(_fit(mesh, d[0], fsdp), None, None)
    if base in ("bq", "bk", "bv"):
        return spec(_fit(mesh, d[0], tp), None)
    if base == "wo":
        heads = d[0]
        if _fit(mesh, heads, tp):
            return spec(tp, None, _fit(mesh, d[2], fsdp))
        return spec(None, None, _fit(mesh, d[2], fsdp))
    if base in ("w_up", "w_gate") and nd - lead == 2:       # dense MLP
        return spec(_fit(mesh, d[0], fsdp), _fit(mesh, d[1], tp))
    if base == "w_down" and nd - lead == 2:
        return spec(_fit(mesh, d[0], tp), _fit(mesh, d[1], fsdp))
    if base == "router":
        return spec(_fit(mesh, d[0], fsdp), None)
    if base == "shared_gate":
        return spec(_fit(mesh, d[0], fsdp), None)
    if base in ("w_up", "w_gate", "w_down") and nd - lead == 3:  # MoE experts
        E = d[0]
        if _fit(mesh, E, tp):                                # expert parallel
            return spec(tp, _fit(mesh, d[1], fsdp), None)
        if base == "w_down":                                 # TP inside expert
            return spec(None, _fit(mesh, d[1], tp), _fit(mesh, d[2], fsdp))
        return spec(None, _fit(mesh, d[1], fsdp), _fit(mesh, d[2], tp))
    # mamba
    if base == "in_proj":
        return spec(_fit(mesh, d[0], fsdp), _fit(mesh, d[1], tp))
    if base == "conv_w":
        return spec(None, _fit(mesh, d[1], tp))
    if base == "x_proj":
        return spec(_fit(mesh, d[0], tp), None)
    if base == "dt_proj":
        return spec(None, _fit(mesh, d[1], tp))
    if base == "A_log":
        return spec(_fit(mesh, d[0], tp), None)
    if base == "out_proj":
        return spec(_fit(mesh, d[0], tp), _fit(mesh, d[1], fsdp))
    # rglru
    if base in ("in_x", "in_gate"):
        return spec(_fit(mesh, d[0], fsdp), _fit(mesh, d[1], tp))
    if base in ("w_a", "w_i"):                    # block-diag (gb, bw, bw)
        return spec(_fit(mesh, d[0], tp), None, None)
    if base == "out":
        return spec(_fit(mesh, d[0], tp), _fit(mesh, d[1], fsdp))
    # fallback: FSDP on the largest dim
    big = max(range(nd - lead), key=lambda i: d[i])
    dims = [None] * (nd - lead)
    dims[big] = _fit(mesh, d[big], fsdp)
    return spec(*dims)


def params_shardings(mesh, cfg, params_shape):
    """Pytree of NamedShardings matching a params eval_shape tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(mesh, cfg, path, leaf)),
        params_shape)


def opt_state_shardings(mesh, cfg, opt_shape, params_shape):
    """Optimizer-state shardings mirror the parameter shardings (ZeRO-style:
    m/v/vr/vc inherit the param pspec where shapes match, else replicate
    scalars / reduced dims)."""
    pspecs = jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(mesh, cfg, path, leaf), params_shape)

    flat_p, _ = jax.tree_util.tree_flatten(params_shape)
    flat_spec, _ = jax.tree_util.tree_flatten(pspecs,
                                              is_leaf=lambda x: isinstance(x, P))

    by_shape = {}
    for leaf, sp in zip(flat_p, flat_spec):
        by_shape.setdefault(leaf.shape, sp)

    def match(leaf):
        if leaf.shape in by_shape:
            return NamedSharding(mesh, by_shape[leaf.shape])
        # factored adafactor stats: drop trailing dims from a matching param
        for shape, sp in by_shape.items():
            for cut in (1, 2):
                if leaf.shape == shape[:-cut]:
                    return NamedSharding(mesh, P(*sp[:len(leaf.shape)]))
            if len(leaf.shape) == len(shape) and all(
                    a == b or a == 1 for a, b in zip(leaf.shape, shape)):
                sp2 = [s if a == b else None
                       for s, a, b in zip(sp, leaf.shape, shape)]
                return NamedSharding(mesh, P(*sp2))
        # vc with shape[:-2] + shape[-1:]
        for shape, sp in by_shape.items():
            if len(shape) >= 2 and leaf.shape == shape[:-2] + shape[-1:]:
                return NamedSharding(mesh, P(*(list(sp[:-2]) + [sp[-1]])))
        return NamedSharding(mesh, P())

    return jax.tree.map(match, opt_shape)


# --------------------------------------------------- activation constraints

def make_constrain(mesh, cfg):
    """with_sharding_constraint hook threaded through the model (ctx hook)."""
    ba = batch_axes(mesh)

    def constrain(x, kind):
        if x.ndim < 2:
            return x
        dims = [None] * x.ndim
        if kind == "residual":                        # (b, s, d)
            dims[0] = _fit(mesh, x.shape[0], ba)
            if x.ndim == 3:
                dims[1] = _fit(mesh, x.shape[1], "model")
        elif kind in ("ffn_hidden", "ssm_inner", "rnn_inner"):  # (b, s, f)
            dims[0] = _fit(mesh, x.shape[0], ba)
            dims[-1] = _fit(mesh, x.shape[-1], "model")
        elif kind == "logits":                        # (b, s, [cb,] V)
            dims[0] = _fit(mesh, x.shape[0], ba)
            dims[-1] = _fit(mesh, x.shape[-1], "model")
        elif kind == "moe_group":                     # (G, gs, d)
            dims[0] = _fit(mesh, x.shape[0], ba)
        elif kind == "moe_buffer":                    # (G, E*C+1, d)
            dims[0] = _fit(mesh, x.shape[0], ba)
            dims[-1] = _fit(mesh, x.shape[-1], "model")
        elif kind == "moe_expert":                    # (G, E, C, d)
            off = x.ndim - 4
            if off >= 0:
                dims[off] = _fit(mesh, x.shape[off], ba)
            dims[off + 1] = _fit(mesh, x.shape[off + 1], "model")
            if dims[off + 1] is None:
                dims[-1] = _fit(mesh, x.shape[-1], "model")
        else:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*dims)))

    return constrain


def batch_shardings(mesh, batch_shape_tree):
    """Inputs: shard dim0 over batch axes, dim1 (seq) unsharded (the
    residual-stream constraint re-shards inside the model)."""
    ba = batch_axes(mesh)

    def one(leaf):
        dims = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1:
            dims[0] = _fit(mesh, leaf.shape[0], ba)
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(one, batch_shape_tree)


def cache_shardings(mesh, cfg, cache_shape_tree):
    """KV caches: batch over ('pod','data'), cache length over 'model'
    (sequence-sharded KV); SSM/RNN states: inner dim over 'model'."""
    ba = batch_axes(mesh)

    def one(path, leaf):
        name = _path_str(path)
        dims = [None] * len(leaf.shape)
        nd = len(leaf.shape)
        if name.endswith("k") or name.endswith("v"):
            # (layers?, b, W, kvh, hd)
            off = nd - 4
            dims[off] = _fit(mesh, leaf.shape[off], ba)
            dims[off + 1] = _fit(mesh, leaf.shape[off + 1], "model")
        elif name.endswith("h"):
            off = 1 if nd in (3, 4) and leaf.shape[0] != leaf.shape[-1] and nd > 2 else 0
            # mamba h (layers?, b, d_in, n); rglru h (layers?, b, w)
            dims[-2 if nd >= 3 else -1] = _fit(mesh, leaf.shape[-2 if nd >= 3 else -1], "model")
            b_dim = nd - (3 if nd >= 3 else 2)
            dims[b_dim] = _fit(mesh, leaf.shape[b_dim], ba)
        elif name.endswith("conv"):
            # (layers?, b, k-1, d)
            dims[-1] = _fit(mesh, leaf.shape[-1], "model")
            dims[len(leaf.shape) - 3] = _fit(mesh, leaf.shape[-3], ba)
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(one, cache_shape_tree)
