"""ShapeDtypeStruct input stand-ins per (architecture × input shape).

No device allocation — these drive ``jit(...).lower(...)`` in the dry-run
and the roofline analysis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES
from repro.models.layers import dtype_of
from repro.models.model import init_cache, init_params


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg, batch, seq):
    tok_shape = (batch, seq, cfg.num_codebooks) if cfg.num_codebooks \
        else (batch, seq)
    specs = {"tokens": sds(tok_shape, jnp.int32),
             "labels": sds(tok_shape, jnp.int32)}
    cd = dtype_of(cfg.compute_dtype)
    if cfg.visual_frontend:
        specs["visual_embeds"] = sds((batch, seq, cfg.d_model), cd)
        specs["visual_mask"] = sds((batch, seq), jnp.bool_)
    if cfg.cross_attention:
        specs["cond"] = sds((batch, cfg.cond_len, cfg.d_model), cd)
    if cfg.pos_emb == "mrope":
        specs["positions3"] = sds((batch, 3, seq), jnp.int32)
    return specs


def prefill_batch_specs(cfg, batch, seq):
    specs = train_batch_specs(cfg, batch, seq)
    specs.pop("labels")
    return specs


def decode_specs(cfg, batch, ctx_len):
    """(tokens, cache, pos, extras) ShapeDtypeStructs for serve_step."""
    tok_shape = (batch, 1, cfg.num_codebooks) if cfg.num_codebooks \
        else (batch, 1)
    sliding = None
    if ctx_len > 65536:
        # long-context decode: sub-quadratic archs carry SSM/RNN state +
        # local windows natively; dense archs use the sliding-window
        # variant (DESIGN.md §4) so the KV cache stays bounded
        sliding = cfg.long_context_window
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, batch, ctx_len, sliding=sliding))
    extras = {}
    cd = dtype_of(cfg.compute_dtype)
    if cfg.cross_attention:
        extras["cond"] = sds((batch, cfg.cond_len, cfg.d_model), cd)
    if cfg.visual_frontend:
        extras["visual_embeds"] = sds((batch, 1, cfg.d_model), cd)
        extras["visual_mask"] = sds((batch, 1), jnp.bool_)
    return (sds(tok_shape, jnp.int32), cache_shape,
            sds((), jnp.int32), extras)


def params_specs(cfg):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def input_specs(cfg, shape_name: str):
    """Public entry: all model inputs for one named input shape."""
    info = INPUT_SHAPES[shape_name]
    b, s = info["global_batch"], info["seq_len"]
    if info["kind"] == "train":
        return {"batch": train_batch_specs(cfg, b, s)}
    if info["kind"] == "prefill":
        return {"batch": prefill_batch_specs(cfg, b, s)}
    tokens, cache, pos, extras = decode_specs(cfg, b, s)
    return {"tokens": tokens, "cache": cache, "pos": pos, "extras": extras}
