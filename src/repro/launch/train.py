"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 100 --batch 8 --seq 256 --ckpt /tmp/ck

Uses the deterministic TokenPipeline, the arch's optimizer, global-norm
clipping and warmup-cosine LR; checkpoints via repro.ckpt.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim.optimizers import make_optimizer, warmup_cosine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override reduced d_model")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(max_d_model=args.d_model or 256,
                          max_layers=args.layers or 2, vocab=2048)
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"params={cfg.param_count()/1e6:.1f}M opt={cfg.optimizer}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    init_opt, _ = make_optimizer(cfg.optimizer)
    opt_state = init_opt(params)
    start = 0
    if args.ckpt:
        try:
            (params, opt_state), start = restore_checkpoint(
                args.ckpt, (params, opt_state))
            print(f"restored step {start} from {args.ckpt}")
        except FileNotFoundError:
            pass

    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0,
                         num_codebooks=cfg.num_codebooks)
    losses = []
    step_fn = None
    t0 = time.time()
    for step in range(start, args.steps):
        lr = warmup_cosine(step, args.lr, warmup_steps=20,
                           total_steps=args.steps)
        if step_fn is None:
            step_fn = jax.jit(make_train_step(cfg, mesh=None, lr=args.lr))
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.float32(lr))
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = (time.time() - t0) / max(1, step - start + 1)
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({dt:.2f}s/step)", flush=True)
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, (params, opt_state), step + 1)
    if args.ckpt:
        save_checkpoint(args.ckpt, (params, opt_state), args.steps)
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
