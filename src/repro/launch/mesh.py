"""Production mesh definitions (TPU v5e pods).

Defined as functions, never module-level constants, so importing this
module does not touch jax device state.
"""
from __future__ import annotations

import jax

# hardware constants for the roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # B/s per chip
ICI_BW = 50e9                  # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_grid_mesh(devices="auto"):
    """1-D device mesh over the simulator's stacked-trace grid axis.

    ``devices="auto"`` (or ``None``) takes every visible device; an
    integer takes the first ``devices`` of them.  The single axis is
    named ``"grid"`` — ``env/jaxsim/driver`` shard_maps the vmapped
    interval program over it, one contiguous slice of grid cells per
    device (cells are embarrassingly parallel, so a 1-D mesh is the
    whole story; there is no model axis to cut)."""
    avail = jax.devices()
    n = len(avail) if devices in ("auto", None) else int(devices)
    if not 1 <= n <= len(avail):
        raise ValueError(f"devices={devices!r}: need 1..{len(avail)} "
                         f"(visible: {len(avail)})")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(avail[:n]), ("grid",))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def num_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
