"""Jittable train / prefill / serve steps with mesh-aware sharding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.launch.sharding import make_constrain
from repro.models.layers import dtype_of
from repro.models.model import decode_step, forward, loss_fn, prefill
from repro.optim.optimizers import clip_by_global_norm, make_optimizer


def make_train_step(cfg, mesh=None, lr=3e-4, clip=1.0):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Gradient accumulation over cfg.grad_accum microbatches via
    lax.scan; grads accumulate in f32 for AdamW and in the param dtype for
    Adafactor (memory headroom on the >=340B configs)."""
    constrain = make_constrain(mesh, cfg) if mesh is not None else None
    _, opt_update = make_optimizer(cfg.optimizer)
    accum_dtype = jnp.float32 if cfg.optimizer == "adamw" else \
        dtype_of(cfg.param_dtype)

    def micro_loss(params, mb):
        total, metrics = loss_fn(params, mb, cfg, constrain)
        return total, metrics

    def train_step(params, opt_state, batch, lr_t=None):
        step_lr = lr if lr_t is None else lr_t
        A = cfg.grad_accum
        if A > 1:
            def split(x):
                return x.reshape((A, x.shape[0] // A) + x.shape[1:])
            micro_batches = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(micro_loss, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g)
                return (g_acc, l_acc + m["ce"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              params)
            (grads, ce), _ = jax.lax.scan(acc_fn, (g0, jnp.zeros(())),
                                          micro_batches)
            grads = jax.tree.map(lambda g: g / A, grads)
            ce = ce / A
        else:
            (l, m), grads = jax.value_and_grad(micro_loss, has_aux=True)(
                params, batch)
            ce = m["ce"]
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = opt_update(grads, opt_state, params, step_lr)
        return params, opt_state, {"loss": ce, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg, mesh=None):
    constrain = make_constrain(mesh, cfg) if mesh is not None else None

    def prefill_step(params, batch):
        logits, cache = prefill(params, batch, cfg, constrain,
                                max_ctx=batch["tokens"].shape[1])
        # serving returns last-position logits only
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg, mesh=None):
    """One-token decode over a KV/state cache (the decode_* dry-run target)."""
    constrain = make_constrain(mesh, cfg) if mesh is not None else None

    def serve_step(params, tokens, cache, pos, extras=None):
        logits, cache = decode_step(params, tokens, cache, pos, cfg,
                                    batch_extras=extras, constrain=constrain)
        return logits[:, -1], cache

    return serve_step


def make_eval_step(cfg, mesh=None):
    constrain = make_constrain(mesh, cfg) if mesh is not None else None

    def eval_step(params, batch):
        logits, _ = forward(params, batch, cfg, constrain)
        return logits

    return eval_step
