from repro.configs.base import ModelConfig, MoEConfig, register

register(ModelConfig(
    name="qwen2-moe-a2.7b", arch_type="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=151936, head_dim=128,
    block_pattern=("attn_moe",),
    qkv_bias=True, activation="silu", mlp_gated=True,
    moe=MoEConfig(num_experts=60, top_k=4, d_ff_expert=1408,
                  num_shared_experts=4, shared_d_ff=5632),
    source="[hf:Qwen/Qwen1.5-MoE-A2.7B] 4 shared + 60 routed top-4",
))
