"""Architecture registry.  Each module registers exactly one ModelConfig."""
import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, RGLRUConfig,
    get_config, all_configs, register,
)

_ARCH_MODULES = [
    "qwen1_5_110b",
    "recurrentgemma_9b",
    "musicgen_medium",
    "qwen2_moe_a2_7b",
    "tinyllama_1_1b",
    "nemotron_4_340b",
    "falcon_mamba_7b",
    "qwen2_vl_7b",
    "kimi_k2_1t_a32b",
    "llama3_405b",
    "splitplace_edge",
]

_loaded = False


def load_all():
    global _loaded
    if _loaded:
        return
    _loaded = True
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


ASSIGNED_ARCHS = [
    "qwen1.5-110b", "recurrentgemma-9b", "musicgen-medium", "qwen2-moe-a2.7b",
    "tinyllama-1.1b", "nemotron-4-340b", "falcon-mamba-7b", "qwen2-vl-7b",
    "kimi-k2-1t-a32b", "llama3-405b",
]

INPUT_SHAPES = {
    "train_4k":    dict(seq_len=4096,   global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768,  global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32768,  global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524288, global_batch=1,   kind="decode"),
}
