from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="tinyllama-1.1b", arch_type="dense",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=5632, vocab_size=32000, head_dim=64,
    activation="silu", mlp_gated=True, rope_theta=10000.0,
    source="[arXiv:2401.02385] llama2-arch small",
))
