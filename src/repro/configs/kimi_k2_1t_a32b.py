from repro.configs.base import ModelConfig, MoEConfig, register

register(ModelConfig(
    name="kimi-k2-1t-a32b", arch_type="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=18432, vocab_size=163840, head_dim=112,
    block_pattern=("attn_moe",),
    activation="silu", mlp_gated=True,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, shared_d_ff=2048,
                  first_k_dense=1),
    optimizer="adafactor", grad_accum=8,
    source="[arXiv:2501.kimi2] trillion-param MoE 384e top-8",
))
