from repro.configs.base import ModelConfig, RGLRUConfig, register

register(ModelConfig(
    name="recurrentgemma-9b", arch_type="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    block_pattern=("rglru", "rglru", "local_attn"),   # 1 attn : 2 recurrent
    activation="gelu", mlp_gated=True,
    rglru=RGLRUConfig(lru_width=4096, conv_kernel=4, local_window=2048),
    grad_accum=4,
    source="[arXiv:2402.19427] RG-LRU + local attn, 1:2, GQA kv=1",
))
