"""The paper's own workload family: small image classifiers (MNIST /
FashionMNIST / CIFAR100 over ResNet/MobileNet/Inception class models),
represented here as the split-able MLP family used by repro.core.splitnets.
Registered so the edge simulator and the TPU serving engine share one
config namespace."""
from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="splitplace-edge", arch_type="dense",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=1024, vocab_size=100,                 # 100-way CIFAR100-style output
    activation="gelu_plain", mlp_gated=False, pos_emb="none",
    param_dtype="float32", compute_dtype="float32",
    source="[paper §6.2] AIoTBench-style edge image-recognition apps",
))
