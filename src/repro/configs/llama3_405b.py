from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="llama3-405b", arch_type="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128256, head_dim=128,
    activation="silu", mlp_gated=True, rope_theta=500000.0,
    optimizer="adafactor", grad_accum=8,
    source="[arXiv:2407.21783] GQA, 128k vocab",
))
