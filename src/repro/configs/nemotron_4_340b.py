from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="nemotron-4-340b", arch_type="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
    d_ff=73728, vocab_size=256000, head_dim=192,
    activation="relu2", mlp_gated=False,      # squared-ReLU, ungated MLP
    rope_fraction=0.5,                        # partial rotary
    optimizer="adafactor", grad_accum=8,
    source="[arXiv:2402.16819] GQA kv=8, squared-ReLU",
))
