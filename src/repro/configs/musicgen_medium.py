from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="musicgen-medium", arch_type="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    block_pattern=("xattn",),                 # self-attn + cross-attn + mlp
    activation="gelu_plain", mlp_gated=False,
    pos_emb="sinusoidal",
    num_codebooks=4, cross_attention=True, cond_len=64,
    source="[arXiv:2306.05284] decoder-only over EnCodec tokens (frontend stub)",
))
