from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="qwen2-vl-7b", arch_type="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    qkv_bias=True, activation="silu", mlp_gated=True,
    pos_emb="mrope", mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0, visual_frontend=True,
    source="[arXiv:2409.12191] M-RoPE, dynamic resolution (ViT stub)",
))
