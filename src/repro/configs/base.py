"""Unified model configuration covering all assigned architecture families.

One frozen dataclass parameterizes dense / MoE / SSM / hybrid / VLM / audio
decoder stacks.  Every per-architecture file in ``repro.configs`` builds one
of these with the exact public-literature numbers and registers it under its
``--arch`` id.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int              # routed experts
    top_k: int
    d_ff_expert: int              # per-expert hidden size
    num_shared_experts: int = 0
    shared_d_ff: int = 0          # total hidden of the shared expert MLP
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    first_k_dense: int = 0        # leading dense (non-MoE) layers
    dispatch: str = "onehot"      # "onehot" (GShard baseline) | "gather" (optimized)
    group_size: int = 4096        # dispatch group (capacity is per group)


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_kernel: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0            # 0 -> d_model
    conv_kernel: int = 4
    local_window: int = 2048      # sliding window of the hybrid's attn layers
    gate_blocks: int = 16         # block-diagonal input/recurrence gates
                                  # (Griffin's parameterization; 1 = dense)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # layer pattern, tiled to cover num_layers (after first_k_dense prefix)
    block_pattern: Tuple[str, ...] = ("attn",)
    # attention details
    qkv_bias: bool = False
    sliding_window: int = 0       # 0 -> full causal attention
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0    # nemotron uses partial rotary (0.5)
    pos_emb: str = "rope"         # rope | mrope | sinusoidal | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # MLP
    activation: str = "silu"      # silu (gated) | gelu (gated) | gelu_plain | relu2
    mlp_gated: bool = True
    # sub-family configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # multimodal
    num_codebooks: int = 0        # musicgen: decoder over EnCodec token stacks
    cross_attention: bool = False # musicgen conditioning
    cond_len: int = 64            # stub conditioning sequence length
    visual_frontend: bool = False # qwen2-vl: merge precomputed patch embeds
    attn_causal_skip: bool = False  # §Perf: triangular block skipping
    ssm_scan_bf16: bool = False     # §Perf: stream scan inputs in bf16
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # training
    optimizer: str = "adamw"      # adamw | adafactor
    grad_accum: int = 1           # microbatch count inside train_step
    remat: bool = True
    # serving: window used for the long-context sliding-window decode variant
    long_context_window: int = 8192
    source: str = ""              # citation for the config numbers

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def dt_rank(self) -> int:
        assert self.ssm is not None
        return self.ssm.dt_rank or math.ceil(self.d_model / 16)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Fully unrolled per-layer block kinds, length == num_layers."""
        prefix = ()
        n = self.num_layers
        if self.moe is not None and self.moe.first_k_dense:
            prefix = ("attn",) * self.moe.first_k_dense
            n -= self.moe.first_k_dense
        reps = -(-n // len(self.block_pattern))
        body = (self.block_pattern * reps)[:n]
        return prefix + body

    @property
    def scan_segments(self):
        """(prefix_kinds, (period_pattern, num_periods), suffix_kinds).

        The body is scanned over whole pattern periods; any leading dense
        prefix (MoE first_k_dense) and trailing partial period are unrolled.
        """
        kinds = self.layer_kinds
        pre = 0
        if self.moe is not None and self.moe.first_k_dense:
            pre = self.moe.first_k_dense
        body = kinds[pre:]
        p = len(self.block_pattern)
        periods = len(body) // p
        rem = len(body) - periods * p
        suffix = body[len(body) - rem:] if rem else ()
        return kinds[:pre], (self.block_pattern, periods), suffix

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        counts = {
            "embed": self.vocab_size * d * max(1, self.num_codebooks or 1),
            "head": self.vocab_size * d * max(1, self.num_codebooks or 1),
        }
        total = counts["embed"] + (0 if self.tie_embeddings else counts["head"])
        for kind in self.layer_kinds:
            total += self._block_params(kind, d, hd)
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k routed only)."""
        if self.moe is None:
            return self.param_count()
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds:
            if kind == "attn_moe":
                total += self._attn_params(d, hd)
                m = self.moe
                total += d * m.num_experts  # router
                total += 3 * d * m.d_ff_expert * m.top_k
                if m.num_shared_experts:
                    total += 3 * d * m.shared_d_ff
                total += 2 * d
            else:
                total += self._block_params(kind, d, hd)
        total += d
        return total

    def _attn_params(self, d, hd):
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        b = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + b

    def _mlp_params(self, d, d_ff):
        return (3 if self.mlp_gated else 2) * d * d_ff

    def _block_params(self, kind, d, hd):
        norms = 2 * d
        if kind == "attn":
            return self._attn_params(d, hd) + self._mlp_params(d, self.d_ff) + norms
        if kind == "local_attn":
            return self._attn_params(d, hd) + self._mlp_params(d, self.d_ff) + norms
        if kind == "xattn":
            return 2 * self._attn_params(d, hd) + self._mlp_params(d, self.d_ff) + 3 * d
        if kind == "attn_moe":
            m = self.moe
            p = self._attn_params(d, hd) + norms + d * m.num_experts
            p += m.num_experts * 3 * d * m.d_ff_expert
            if m.num_shared_experts:
                p += 3 * d * m.shared_d_ff
            return p
        if kind == "mamba":
            s = self.ssm
            d_in = s.expand * d
            p = d * 2 * d_in                       # in_proj
            p += d_in * s.conv_kernel + d_in       # conv + bias
            p += d_in * (self.dt_rank + 2 * s.state_dim)  # x_proj
            p += self.dt_rank * d_in + d_in        # dt_proj
            p += d_in * s.state_dim + d_in         # A_log, D
            p += d_in * d                          # out_proj
            return p + d                           # norm
        if kind == "rglru":
            r = self.rglru
            w = r.lru_width or d
            p = d * w * 2                          # x & gate projections
            p += w * r.conv_kernel + w             # conv
            gb = max(1, r.gate_blocks)
            p += 2 * (w * w // gb + w)             # block-diag gates
            p += w                                 # Lambda
            p += w * d                             # out proj
            return p + self._mlp_params(d, self.d_ff) + 2 * d
        raise ValueError(kind)

    def reduced(self, max_d_model: int = 256, max_layers: int = 2,
                max_experts: int = 4, vocab: int = 128) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        d = min(self.d_model, max_d_model)
        hd = 32
        heads = max(2, d // 64)
        kv = max(1, min(self.num_kv_heads, heads // 2)) if self.num_kv_heads < self.num_heads else heads
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, max_experts),
                top_k=min(self.moe.top_k, 2), d_ff_expert=d,
                shared_d_ff=d if self.moe.num_shared_experts else 0,
                first_k_dense=min(self.moe.first_k_dense, 1),
                capacity_factor=4.0)  # lossless routing for smoke tests
        layers = max_layers
        if self.moe is not None and self.moe.first_k_dense:
            layers = max_layers + 1
        if len(self.block_pattern) > 1:
            layers = len(self.block_pattern) + 1  # one full period + remainder
        half = hd // 2
        t = max(1, half // 4)
        sections = (t, (half - t) // 2, half - t - (half - t) // 2)
        return dataclasses.replace(
            self, num_layers=layers, d_model=d, num_heads=heads,
            num_kv_heads=kv, head_dim=hd, d_ff=2 * d, vocab_size=vocab,
            moe=moe, mrope_sections=sections,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            rglru=dataclasses.replace(self.rglru, lru_width=d, local_window=8) if self.rglru else None,
            param_dtype="float32", compute_dtype="float32",
            grad_accum=1, cond_len=4,
        )


_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import side-effect registration
    from repro import configs as _c  # noqa: F401
    _c.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs():
    from repro import configs as _c
    _c.load_all()
    return dict(_REGISTRY)
