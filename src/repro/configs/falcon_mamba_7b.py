from repro.configs.base import ModelConfig, SSMConfig, register

register(ModelConfig(
    name="falcon-mamba-7b", arch_type="ssm",
    num_layers=64, d_model=4096, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=65024,
    block_pattern=("mamba",), pos_emb="none",
    ssm=SSMConfig(state_dim=16, conv_kernel=4, expand=2),
    grad_accum=4,
    source="[arXiv:2410.05355] mamba1 arch, attn-free, ssm_state=16",
))
