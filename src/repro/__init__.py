"""repro: SplitPlace (Tuli et al., 2022) reproduced as a production-grade
JAX training/serving framework for multi-pod TPU, plus the paper's own
mobile-edge simulation testbed."""
__version__ = "1.0.0"
