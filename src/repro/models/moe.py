"""Mixture-of-Experts FFN: shared experts + routed top-k experts.

Tokens are dispatched in GROUPS (GShard-style): capacity and slot
positions are per-group, so dispatch tensors are (G, gs, E, C) with
gs = group_size — the group dim shards over the batch axes and experts
over the model axis (expert parallelism).

Two dispatch implementations:

* ``onehot`` — GShard/Switch-style capacity dispatch via one-hot einsums.
  Faithful baseline; dispatch einsum costs O(gs^2 · k · cf · d) per group.
* ``gather`` — scatter/gather dispatch: same routing, O(gs · k · d) data
  movement and no one-hot matmuls.  The §Perf hillclimb variant.

Semantic-split note (paper mapping): the router IS the paper's semantic
input->branch assignment; expert-group partitioning over the `model` mesh
axis realizes the semantic-split placement natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import activation_fn, dense_init, mlp_apply, mlp_init


def moe_init(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d, m.num_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (m.num_experts, d, m.d_ff_expert), dtype),
        "w_up": dense_init(ks[2], (m.num_experts, d, m.d_ff_expert), dtype),
        "w_down": dense_init(ks[3], (m.num_experts, m.d_ff_expert, d), dtype,
                             fan_in=m.d_ff_expert),
    }
    if m.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, m.shared_d_ff, cfg, dtype)
        p["shared_gate"] = dense_init(ks[5], (d, 1), jnp.float32)
    return p


def router_topk(p, x2d, m):
    """x2d (..., d) -> (gates (..., k), idx (..., k), probs (..., E))."""
    logits = x2d.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, m.top_k)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)
    return top_vals, top_idx, probs


def _group(x, m):
    """(b, s, d) -> (G, gs, d) padded token groups + original count."""
    b, s, d = x.shape
    S = b * s
    gs = min(m.group_size, S)
    pad = (-S) % gs
    x2 = x.reshape(S, d)
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    return x2.reshape(-1, gs, d), S, gs


def _capacity(gs, m):
    return max(int(gs * m.top_k / m.num_experts * m.capacity_factor),
               m.top_k)


def _expert_ffn(p, xin, cfg):
    """xin (G, E, C, d) -> (G, E, C, d), per-expert gated MLP."""
    act = activation_fn(cfg.activation)
    h = act(jnp.einsum("gecd,edf->gecf", xin, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    return jnp.einsum("gecf,efd->gecd", h, p["w_down"])


def moe_apply_onehot(p, x, cfg, constrain=None):
    m = cfg.moe
    b, s, d = x.shape
    xg, S, gs = _group(x, m)
    if constrain is not None:
        # group-parallel re-shard: the (b·s)->groups reshape mixes the
        # batch- and seq-sharded dims; without a target GSPMD all-gathers
        # the full activation (observed 18x collective blowup multi-pod)
        xg = constrain(xg, "moe_group")
    G = xg.shape[0]
    C = _capacity(gs, m)
    top_vals, top_idx, _ = router_topk(p, xg, m)            # (G, gs, k)
    expert_onehot = jax.nn.one_hot(top_idx, m.num_experts, dtype=jnp.int32)
    # slot within expert: prefix count inside the group over the flattened
    # (token, choice) order — per-k cumsum would collide slots
    flat = expert_onehot.reshape(G, gs * m.top_k, m.num_experts)
    pos = (jnp.cumsum(flat, axis=1) - 1) * flat
    pos = pos.sum(-1).reshape(G, gs, m.top_k)               # (G, gs, k)
    keep = pos < C
    slot_onehot = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                                 dtype=x.dtype)[..., :C]    # (G, gs, k, C)
    eo = expert_onehot.astype(x.dtype)
    disp = jnp.einsum("gske,gskc->gsec", eo, slot_onehot)   # (G, gs, E, C)
    combine = jnp.einsum("gske,gskc,gsk->gsec", eo, slot_onehot,
                         top_vals.astype(x.dtype))
    xin = jnp.einsum("gsec,gsd->gecd", disp, xg)
    if constrain is not None:
        xin = constrain(xin, "moe_expert")
    xout = _expert_ffn(p, xin, cfg)
    y = jnp.einsum("gsec,gecd->gsd", combine, xout)
    y = y.reshape(-1, d)[:S]
    y = _add_shared(p, x.reshape(S, d), y, cfg)
    return y.reshape(b, s, d)


def moe_apply_gather(p, x, cfg, constrain=None):
    """Scatter/gather dispatch: same routing & capacity semantics as the
    onehot path (matches it exactly when nothing overflows), but token
    movement is O(gs·k·d) gathers instead of O(gs·E·C·d) einsums."""
    m = cfg.moe
    b, s, d = x.shape
    xg, S, gs = _group(x, m)
    if constrain is not None:
        xg = constrain(xg, "moe_group")
    G = xg.shape[0]
    C = _capacity(gs, m)
    top_vals, top_idx, _ = router_topk(p, xg, m)
    flat_e = top_idx.reshape(G, gs * m.top_k)               # (G, N)
    onehot_cnt = jax.nn.one_hot(flat_e, m.num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot_cnt, axis=1) - 1                # (G, N, E)
    slot = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = slot < C
    dest = jnp.where(keep, flat_e * C + slot, m.num_experts * C)  # (G, N)
    token_ids = jnp.arange(gs).repeat(m.top_k)[None].repeat(G, 0)
    buf = jnp.zeros((G, m.num_experts * C + 1, d), x.dtype)
    gidx = jnp.arange(G)[:, None].repeat(gs * m.top_k, 1)
    src = jnp.take_along_axis(xg, token_ids[..., None], axis=1)
    if constrain is not None:
        # keep the scatter group-local: G over batch axes, d over model —
        # without this GSPMD replicates the (G, E*C, d) buffer (§Perf it.2)
        buf = constrain(buf, "moe_buffer")
        src = constrain(src, "moe_buffer")
    buf = buf.at[gidx, dest].set(src)
    xin = buf[:, :-1].reshape(G, m.num_experts, C, d)
    if constrain is not None:
        xin = constrain(xin, "moe_expert")
    xout = _expert_ffn(p, xin, cfg).reshape(G, m.num_experts * C, d)
    xout = jnp.concatenate(
        [xout, jnp.zeros((G, 1, d), xout.dtype)], axis=1)
    if constrain is not None:
        xout = constrain(xout, "moe_buffer")
    gathered = jnp.take_along_axis(xout, dest[..., None], axis=1)
    gathered = gathered.reshape(G, gs, m.top_k, d)
    w = (top_vals * keep.reshape(G, gs, m.top_k)).astype(x.dtype)
    y = jnp.einsum("gskd,gsk->gsd", gathered, w)
    y = y.reshape(-1, d)[:S]
    y = _add_shared(p, x.reshape(S, d), y, cfg)
    return y.reshape(b, s, d)


def _add_shared(p, x2, y, cfg):
    if cfg.moe.num_shared_experts:
        gate = jax.nn.sigmoid(x2.astype(jnp.float32) @ p["shared_gate"])
        y = y + (mlp_apply(p["shared"], x2, cfg) * gate.astype(x2.dtype))
    return y


def moe_apply(p, x, cfg, constrain=None):
    if cfg.moe.dispatch == "gather":
        return moe_apply_gather(p, x, cfg, constrain)
    return moe_apply_onehot(p, x, cfg, constrain)


def aux_load_balance_loss(p, x, cfg):
    """Switch-style auxiliary load-balance loss (mean fraction * mean prob)."""
    m = cfg.moe
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    _, top_idx, probs = router_topk(p, x2, m)
    frac = jax.nn.one_hot(top_idx, m.num_experts).sum(1).mean(0)  # (E,)
    return m.num_experts * jnp.sum(frac * probs.mean(0))
