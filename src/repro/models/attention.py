"""GQA attention: full, blockwise (flash-style online-softmax), and decode.

The blockwise path is the pure-JAX twin of ``repro.kernels.flash_attention``
(the Pallas TPU kernel) and doubles as its oracle; the model uses this path
for long sequences so compiled temporaries stay O(block) instead of O(seq^2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import apply_mrope, apply_rope, dense_init

NEG_INF = -1e30


def attn_init(key, cfg, dtype, cross=False):
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dtype),
        "wk": dense_init(ks[1], (d, k, hd), dtype),
        "wv": dense_init(ks[2], (d, k, hd), dtype),
        "wo": dense_init(ks[3], (h, hd, d), dtype, fan_in=h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((k, hd), dtype)
        p["bv"] = jnp.zeros((k, hd), dtype)
    return p


def project_qkv(p, x, cfg):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dke->bske", x, p["wk"])
    v = jnp.einsum("bsd,dke->bske", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def _rope_qk(q, k, ctx, cfg):
    if cfg.pos_emb == "rope":
        q = apply_rope(q, ctx["positions"], cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, ctx["positions"], cfg.rope_theta, cfg.rope_fraction)
    elif cfg.pos_emb == "mrope":
        q = apply_mrope(q, ctx["positions3"], cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, ctx["positions3"], cfg.mrope_sections, cfg.rope_theta)
    return q, k


def _group(q, num_kv):
    b, s, h, hd = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, hd)


def full_attention(q, k, v, pos_q, pos_k, window=0, kv_mask=None, causal=True):
    """Reference full-materialization attention.

    q (b,sq,h,hd); k,v (b,sk,kv,hd); pos_q (b,sq); pos_k (b,sk).
    """
    kvh = k.shape[2]
    qg = _group(q, kvh)                                     # (b,sq,kv,g,hd)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    mask = jnp.ones(scores.shape[-2:], bool)[None]
    if causal:
        mask = pos_q[:, :, None] >= pos_k[:, None, :]
    if window:
        mask &= (pos_q[:, :, None] - pos_k[:, None, :]) < window
    if kv_mask is not None:
        mask &= kv_mask[:, None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    b, sq = q.shape[:2]
    return out.reshape(b, sq, -1, q.shape[-1])


def blockwise_attention(q, k, v, pos_q, pos_k, window=0,
                        q_block=512, kv_block=1024, causal_skip=False):
    """Flash-style attention: scan q blocks; stream kv blocks (online softmax).

    With ``causal_skip`` the kv scan for q-block i only covers kv blocks
    0..ceil that can be unmasked (static upper-triangular skipping), halving
    the compute term for causal attention.
    """
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    nq, nk = -(-sq // q_block), -(-sk // kv_block)
    pq = nq * q_block - sq
    pk = nk * kv_block - sk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    pqp = jnp.pad(pos_q, ((0, 0), (0, pq)), constant_values=-1)
    pkp = jnp.pad(pos_k, ((0, 0), (0, pk)), constant_values=2**30)
    qb = qp.reshape(b, nq, q_block, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = kp.reshape(b, nk, kv_block, kvh, hd)
    vb = vp.reshape(b, nk, kv_block, kvh, hd)
    pqb = pqp.reshape(b, nq, q_block).transpose(1, 0, 2)
    scale = hd ** -0.5

    def one_q_block(args, kv_hi=None):
        qi, posq, q_idx = args                              # (b,qb,kv,g,hd)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, vi, posk, k_idx = inputs
            s = jnp.einsum("bqkgh,bskh->bkgqs", qi, ki).astype(jnp.float32) * scale
            mask = posq[:, :, None] >= posk[:, None, :]
            if window:
                mask &= (posq[:, :, None] - posk[:, None, :]) < window
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(vi.dtype), vi).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_block, hd), jnp.float32)
        hi = nk if kv_hi is None else kv_hi
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.transpose(1, 0, 2, 3, 4)[:hi],
             vb.transpose(1, 0, 2, 3, 4)[:hi],
             pkp.reshape(b, nk, kv_block).transpose(1, 0, 2)[:hi],
             jnp.arange(nk)[:hi]))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)                 # (b,qb,kv,g,hd)

    if causal_skip:
        # §Perf: static upper-triangular skipping — q block i only visits
        # kv blocks 0..ceil((i+1)*qb/kb), halving causal-attention FLOPs.
        # Unrolled per-q-block scans keep trip counts static (honest
        # roofline counting; dynamic fori bounds hide work from both XLA
        # and the jaxpr counter).
        outs = []
        for i in range(nq):
            hi = min(-(-((i + 1) * q_block) // kv_block), nk)
            fn = jax.checkpoint(functools.partial(one_q_block, kv_hi=hi))
            outs.append(fn((qb[i], pqb[i], i)))
        out = jnp.stack(outs, 0)
    else:
        # flash-style memory under AD: recompute score blocks in backward
        # instead of saving the O(s^2) inner-scan residuals
        out = jax.lax.map(jax.checkpoint(one_q_block),
                          (qb, pqb, jnp.arange(nq)))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * q_block, h, hd)
    return out[:, :sq].astype(q.dtype)


def self_attention(p, x, ctx, cfg, window=0):
    """Full-sequence self attention (train / prefill)."""
    q, k, v = project_qkv(p, x, cfg)
    q, k = _rope_qk(q, k, ctx, cfg)
    pos = ctx["positions"]
    if x.shape[1] > ctx.get("blockwise_threshold", 2048):
        out = blockwise_attention(q, k, v, pos, pos, window=window,
                                  causal_skip=ctx.get("causal_skip", False))
    else:
        out = full_attention(q, k, v, pos, pos, window=window)
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    return y, (k, v)


def cross_attention(p, x, cond, cfg):
    """x (b,s,d) attends to cond (b,n,d); no causal mask, no rope."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bnd,dke->bnke", cond, p["wk"])
    v = jnp.einsum("bnd,dke->bnke", cond, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    b, s = x.shape[:2]
    n = cond.shape[1]
    pos_q = jnp.full((b, s), n, jnp.int32)
    pos_k = jnp.zeros((b, n), jnp.int32)
    out = full_attention(q, k, v, pos_q, pos_k, causal=False)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


# ----------------------------------------------------------- decoding

def init_attn_cache(cfg, batch, ctx_len, window=0, dtype=jnp.bfloat16):
    w = min(ctx_len, window) if window else ctx_len
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, w, kvh, hd), dtype),
        "v": jnp.zeros((batch, w, kvh, hd), dtype),
    }


def decode_attention(p, x, cache, pos, ctx, cfg, window=0):
    """One-token decode.  x (b,1,d); pos scalar int32 (current position).

    The cache is a ring buffer of size W; attention is permutation-invariant
    over kv slots so ring order needs no unrotation.
    """
    q, k, v = project_qkv(p, x, cfg)
    b = x.shape[0]
    pos_b = jnp.full((b, 1), pos, jnp.int32)
    if cfg.pos_emb == "mrope":
        p3 = jnp.broadcast_to(pos_b[:, None, :], (b, 3, 1))
        q = apply_mrope(q, p3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, p3, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.pos_emb == "rope":
        q = apply_rope(q, pos_b, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, pos_b, cfg.rope_theta, cfg.rope_fraction)
    W = cache["k"].shape[1]
    slot = (pos % W).astype(jnp.int32)
    # mask-based ring write: dynamic_update_slice on a sharded cache dim
    # makes GSPMD all-gather the cache; a select against iota is purely
    # elementwise and keeps the seq-sharded layout (§Perf iteration 0)
    hit = (jnp.arange(W, dtype=jnp.int32) == slot)[None, :, None, None]
    ck = jnp.where(hit, k.astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(hit, v.astype(cache["v"].dtype), cache["v"])
    valid = jnp.arange(W)[None, :] < jnp.minimum(pos + 1, W)
    valid = jnp.broadcast_to(valid, (b, W))
    pos_k = jnp.where(valid, 0, 2**30)                      # mask via pos trick
    out = full_attention(q, ck, cv, jnp.ones_like(pos_b), pos_k,
                         causal=True, window=0)
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}
