from repro.models.model import (  # noqa: F401
    init_params, forward, loss_fn, prefill, init_cache, decode_step,
)
