"""Unified decoder model over all block kinds.

Public API:
    init_params(key, cfg)                         -> params pytree
    forward(params, batch, cfg, ...)              -> (logits, aux)
    loss_fn(params, batch, cfg, ...)              -> (scalar, metrics)
    prefill(params, batch, cfg, ...)              -> (logits, cache)
    init_cache(cfg, batch, ctx_len, sliding)      -> cache pytree
    decode_step(params, tokens, cache, pos, ...)  -> (logits, cache)

The layer stack is organized as (prefix, scanned body of pattern periods,
suffix): the body is a ``lax.scan`` over stacked period parameters (with
optional remat), keeping the HLO O(1) in depth; MoE first-k-dense prefixes
and partial trailing periods are unrolled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (dense_init, dtype_of, mlp_apply, mlp_init,
                                 rmsnorm, sinusoidal_embedding)


def _identity_constrain(x, kind):
    return x


# ------------------------------------------------------------ block init

def init_block(key, kind, cfg):
    dtype = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind in ("attn", "local_attn"):
        return {"norm1": jnp.zeros((d,), dtype),
                "attn": attn.attn_init(ks[0], cfg, dtype),
                "norm2": jnp.zeros((d,), dtype),
                "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg, dtype)}
    if kind == "xattn":
        return {"norm1": jnp.zeros((d,), dtype),
                "attn": attn.attn_init(ks[0], cfg, dtype),
                "norm_x": jnp.zeros((d,), dtype),
                "xattn": attn.attn_init(ks[1], cfg, dtype, cross=True),
                "norm2": jnp.zeros((d,), dtype),
                "mlp": mlp_init(ks[2], d, cfg.d_ff, cfg, dtype)}
    if kind == "attn_moe":
        return {"norm1": jnp.zeros((d,), dtype),
                "attn": attn.attn_init(ks[0], cfg, dtype),
                "norm2": jnp.zeros((d,), dtype),
                "moe": moe_mod.moe_init(ks[1], cfg, dtype)}
    if kind == "mamba":
        return {"norm1": jnp.zeros((d,), dtype),
                "mamba": ssm_mod.mamba_init(ks[0], cfg, dtype)}
    if kind == "rglru":
        return {"norm1": jnp.zeros((d,), dtype),
                "rglru": rglru_mod.rglru_init(ks[0], cfg, dtype),
                "norm2": jnp.zeros((d,), dtype),
                "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg, dtype)}
    raise ValueError(kind)


def _block_window(kind, cfg):
    if kind == "local_attn":
        return cfg.rglru.local_window
    return cfg.sliding_window


# --------------------------------------------------------- block apply

def apply_block(kind, p, x, ctx, cfg, collect_cache=False):
    """Returns (x, aux_loss, cache_or_None)."""
    con = ctx.get("constrain", _identity_constrain)
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind in ("attn", "local_attn", "xattn", "attn_moe"):
        h, kv = attn.self_attention(p["attn"], rmsnorm(x, p["norm1"], cfg.norm_eps),
                                    ctx, cfg, window=_block_window(kind, cfg))
        x = con(x + h, "residual")
        if collect_cache:
            w = _block_window(kind, cfg) or ctx["cache_len"]
            w = min(w, ctx["cache_len"])
            k, v = kv
            s = k.shape[1]
            dt = dtype_of(cfg.compute_dtype)
            if s >= w:
                # keep last w entries, rolled so slot j holds pos ≡ j (mod w)
                shift = (s - w) % w
                k2 = jnp.roll(k[:, s - w:], shift, axis=1)
                v2 = jnp.roll(v[:, s - w:], shift, axis=1)
            else:
                pad = ((0, 0), (0, w - s), (0, 0), (0, 0))
                k2, v2 = jnp.pad(k, pad), jnp.pad(v, pad)
            cache = {"k": k2.astype(dt), "v": v2.astype(dt)}
        if kind == "xattn":
            hx = attn.cross_attention(p["xattn"],
                                      rmsnorm(x, p["norm_x"], cfg.norm_eps),
                                      ctx["cond"], cfg)
            x = con(x + hx, "residual")
        if kind == "attn_moe":
            xn = rmsnorm(x, p["norm2"], cfg.norm_eps)
            h2 = moe_mod.moe_apply(p["moe"], xn, cfg, con)
            aux = moe_mod.aux_load_balance_loss(p["moe"], xn, cfg)
            x = con(x + h2, "residual")
        else:
            h2 = mlp_apply(p["mlp"], rmsnorm(x, p["norm2"], cfg.norm_eps), cfg, con)
            x = con(x + h2, "residual")
        return x, aux, cache
    if kind == "mamba":
        if collect_cache:
            y, cache = ssm_mod.mamba_prefill(p["mamba"],
                                             rmsnorm(x, p["norm1"], cfg.norm_eps),
                                             cfg, con)
        else:
            y = ssm_mod.mamba_apply(p["mamba"],
                                    rmsnorm(x, p["norm1"], cfg.norm_eps), cfg, con)
        return con(x + y, "residual"), aux, cache
    if kind == "rglru":
        if collect_cache:
            y, cache = rglru_mod.rglru_prefill(
                p["rglru"], rmsnorm(x, p["norm1"], cfg.norm_eps), cfg, con)
        else:
            y = rglru_mod.rglru_apply(p["rglru"],
                                      rmsnorm(x, p["norm1"], cfg.norm_eps), cfg, con)
        x = con(x + y, "residual")
        h2 = mlp_apply(p["mlp"], rmsnorm(x, p["norm2"], cfg.norm_eps), cfg, con)
        return con(x + h2, "residual"), aux, cache
    raise ValueError(kind)


def decode_block(kind, p, x, cache, pos, ctx, cfg):
    con = ctx.get("constrain", _identity_constrain)
    if kind in ("attn", "local_attn", "xattn", "attn_moe"):
        h, cache_a = attn.decode_attention(
            p["attn"], rmsnorm(x, p["norm1"], cfg.norm_eps), cache, pos, ctx, cfg)
        x = x + h
        if kind == "xattn":
            hx = attn.cross_attention(p["xattn"],
                                      rmsnorm(x, p["norm_x"], cfg.norm_eps),
                                      ctx["cond"], cfg)
            x = x + hx
        xn = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if kind == "attn_moe":
            x = x + moe_mod.moe_apply(p["moe"], xn, cfg, con)
        else:
            x = x + mlp_apply(p["mlp"], xn, cfg, con)
        return x, cache_a
    if kind == "mamba":
        y, cache = ssm_mod.mamba_decode(p["mamba"],
                                        rmsnorm(x, p["norm1"], cfg.norm_eps),
                                        cache, cfg)
        return x + y, cache
    if kind == "rglru":
        y, cache = rglru_mod.rglru_decode(p["rglru"],
                                          rmsnorm(x, p["norm1"], cfg.norm_eps),
                                          cache, cfg)
        x = x + y
        x = x + mlp_apply(p["mlp"], rmsnorm(x, p["norm2"], cfg.norm_eps), cfg, con)
        return x, cache
    raise ValueError(kind)


def init_block_cache(kind, cfg, batch, ctx_len, sliding=None):
    dtype = dtype_of(cfg.compute_dtype)
    if kind in ("attn", "xattn", "attn_moe"):
        w = cfg.sliding_window or (sliding or ctx_len)
        return attn.init_attn_cache(cfg, batch, ctx_len, window=w, dtype=dtype)
    if kind == "local_attn":
        return attn.init_attn_cache(cfg, batch, ctx_len,
                                    window=cfg.rglru.local_window, dtype=dtype)
    if kind == "mamba":
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    if kind == "rglru":
        return rglru_mod.init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


# ----------------------------------------------------------- embeddings

def init_params(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    d, v = cfg.d_model, cfg.vocab_size
    kE, kH, kB = jax.random.split(key, 3)
    cb = cfg.num_codebooks
    params = {
        "embed": dense_init(kE, (cb, v, d) if cb else (v, d), dtype, fan_in=d),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(kH, (cb, d, v) if cb else (d, v), dtype)
    prefix, (pattern, periods), suffix = cfg.scan_segments
    keys = jax.random.split(kB, len(prefix) + periods + len(suffix) + 1)
    params["prefix"] = [init_block(keys[i], k, cfg) for i, k in enumerate(prefix)]

    def init_period(pk):
        pks = jax.random.split(pk, len(pattern))
        return {f"b{j}": init_block(pks[j], kind, cfg)
                for j, kind in enumerate(pattern)}

    if periods:
        params["body"] = jax.vmap(init_period)(
            jax.random.split(keys[len(prefix)], periods))
    params["suffix"] = [init_block(keys[len(prefix) + 1 + i], k, cfg)
                        for i, k in enumerate(suffix)]
    return params


def embed_tokens(params, batch, cfg, positions):
    tokens = batch["tokens"]
    if cfg.num_codebooks:
        # tokens (b, s, cb): sum codebook embeddings
        x = sum(jnp.take(params["embed"][i], tokens[..., i], axis=0)
                for i in range(cfg.num_codebooks))
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.visual_frontend and "visual_embeds" in batch:
        mask = batch["visual_mask"][..., None]
        x = jnp.where(mask, batch["visual_embeds"].astype(x.dtype), x)
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_embedding(positions, cfg.d_model).astype(x.dtype)
    return x.astype(dtype_of(cfg.compute_dtype))


def lm_head(params, x, cfg):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].swapaxes(-1, -2) if cfg.tie_embeddings else params["head"]
    if cfg.num_codebooks:
        return jnp.einsum("bsd,cdv->bscv", x, w).astype(jnp.float32)
    return (x @ w).astype(jnp.float32)


def _make_ctx(batch, cfg, constrain, cache_len=0):
    b, s = batch["tokens"].shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    ctx = {"positions": positions, "constrain": constrain or _identity_constrain,
           "cache_len": cache_len,
           "causal_skip": getattr(cfg, "attn_causal_skip", False)}
    if cfg.pos_emb == "mrope":
        p3 = batch.get("positions3")
        if p3 is None:
            p3 = jnp.broadcast_to(positions[:, None, :], (b, 3, s))
        ctx["positions3"] = p3
    if cfg.cross_attention:
        cond = batch.get("cond")
        if cond is None:
            cond = jnp.zeros((b, cfg.cond_len, cfg.d_model),
                             dtype_of(cfg.compute_dtype))
        ctx["cond"] = cond
    return ctx


# ------------------------------------------------------------- forward

def forward(params, batch, cfg, constrain=None, collect_cache=False,
            max_ctx=None):
    """Full-sequence forward.  Returns (logits, aux_loss[, cache])."""
    ctx = _make_ctx(batch, cfg, constrain,
                    cache_len=max_ctx or batch["tokens"].shape[1])
    x = embed_tokens(params, batch, cfg, ctx["positions"])
    x = ctx["constrain"](x, "residual")
    prefix, (pattern, periods), suffix = cfg.scan_segments
    aux = jnp.zeros((), jnp.float32)
    caches = {"prefix": [], "suffix": []}
    for p, kind in zip(params["prefix"], prefix):
        x, a, c = apply_block(kind, p, x, ctx, cfg, collect_cache)
        aux, _ = aux + a, caches["prefix"].append(c)

    if periods:
        def period_fn(carry, pp):
            x, aux = carry
            cs = {}
            for j, kind in enumerate(pattern):
                x, a, c = apply_block(kind, pp[f"b{j}"], x, ctx, cfg,
                                      collect_cache)
                aux = aux + a
                if collect_cache:
                    cs[f"b{j}"] = c
            return (x, aux), cs
        fn = jax.checkpoint(period_fn) if cfg.remat else period_fn
        (x, aux), body_cache = jax.lax.scan(fn, (x, aux), params["body"])
        if collect_cache:
            caches["body"] = body_cache

    for p, kind in zip(params["suffix"], suffix):
        x, a, c = apply_block(kind, p, x, ctx, cfg, collect_cache)
        aux, _ = aux + a, caches["suffix"].append(c)

    logits = lm_head(params, x, cfg)
    if collect_cache:
        return logits, aux, caches
    return logits, aux


def loss_fn(params, batch, cfg, constrain=None, aux_weight=0.01):
    logits, aux = forward(params, batch, cfg, constrain)
    labels = batch["labels"]
    # sharding-safe CE: logsumexp reduces over the (vocab-sharded) last dim
    # and the label logit is a contraction — no gather that would force an
    # all-gather of the full logits
    con = constrain or _identity_constrain
    logits = con(logits, "logits")
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = con(jax.nn.one_hot(labels, logits.shape[-1],
                                dtype=logits.dtype), "logits")
    label_logit = jnp.einsum("...v,...v->...", logits, onehot)
    nll = lse - label_logit
    loss = nll.mean()
    total = loss + aux_weight * aux
    return total, {"ce": loss, "aux": aux}


def prefill(params, batch, cfg, constrain=None, max_ctx=None):
    """Full-seq forward returning logits + a decode cache.

    ``max_ctx`` sets the allocated KV-cache length (defaults to seq + 32 so
    decoding can continue past the prompt without ring-wrap).
    """
    if max_ctx is None:
        max_ctx = batch["tokens"].shape[1] + 32
    logits, aux, cache = forward(params, batch, cfg, constrain,
                                 collect_cache=True, max_ctx=max_ctx)
    return logits, cache


# -------------------------------------------------------------- decode

def init_cache(cfg, batch, ctx_len, sliding=None):
    prefix, (pattern, periods), suffix = cfg.scan_segments
    mk = lambda kind: init_block_cache(kind, cfg, batch, ctx_len, sliding)
    cache = {"prefix": [mk(k) for k in prefix],
             "suffix": [mk(k) for k in suffix]}
    if periods:
        period = {f"b{j}": mk(kind) for j, kind in enumerate(pattern)}
        cache["body"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (periods,) + a.shape), period)
    return cache


def decode_step(params, tokens, cache, pos, cfg, batch_extras=None,
                constrain=None):
    """One-token decode.

    tokens (b, 1) or (b, 1, cb); pos scalar int32; cache from init_cache /
    prefill.  Returns (logits, new_cache).
    """
    batch = {"tokens": tokens}
    if batch_extras:
        batch.update(batch_extras)
    ctx = _make_ctx(batch, cfg, constrain)
    b = tokens.shape[0]
    ctx["positions"] = jnp.full((b, 1), pos, jnp.int32)
    x = embed_tokens(params, batch, cfg, ctx["positions"])
    prefix, (pattern, periods), suffix = cfg.scan_segments
    new_cache = {"prefix": [], "suffix": []}
    for p, kind, c in zip(params["prefix"], prefix, cache["prefix"]):
        x, nc = decode_block(kind, p, x, c, pos, ctx, cfg)
        new_cache["prefix"].append(nc)
    if periods:
        def f(x, pc):
            pp, cc = pc
            ncs = {}
            for j, kind in enumerate(pattern):
                x, ncs[f"b{j}"] = decode_block(kind, pp[f"b{j}"], x,
                                               cc[f"b{j}"], pos, ctx, cfg)
            return x, ncs
        x, body_cache = jax.lax.scan(f, x, (params["body"], cache["body"]))
        new_cache["body"] = body_cache
    for p, kind, c in zip(params["suffix"], suffix, cache["suffix"]):
        x, nc = decode_block(kind, p, x, c, pos, ctx, cfg)
        new_cache["suffix"].append(nc)
    logits = lm_head(params, x, cfg)
    return logits, new_cache
