"""Shared neural building blocks: norms, MLPs, position embeddings, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rmsnorm(x, weight, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name in ("gelu", "gelu_plain"):
        return jax.nn.gelu
    if name == "relu2":  # nemotron squared-ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ----------------------------------------------------------------- MLP

def mlp_init(key, d_model, d_ff, cfg, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d_model, d_ff), dtype),
         "w_down": dense_init(ks[1], (d_ff, d_model), dtype, fan_in=d_ff)}
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def mlp_apply(p, x, cfg, constrain=None):
    act = activation_fn(cfg.activation)
    up = x @ p["w_up"]
    if cfg.mlp_gated:
        h = act(x @ p["w_gate"]) * up
    else:
        h = act(up)
    if constrain is not None:
        h = constrain(h, "ffn_hidden")
    return h @ p["w_down"]


# ---------------------------------------------------------------- RoPE

def rope_angles(positions, dim, theta, dtype=jnp.float32):
    """positions (...,) -> cos/sin of shape (..., dim//2)."""
    freqs = (theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, positions, theta=10000.0, fraction=1.0):
    """x (b, s, h, hd); positions (b, s). Rotates leading `fraction` of hd."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    cos, sin = rope_angles(positions, rot, theta)          # (b, s, rot/2)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([y, xp], axis=-1) if rot < hd else y


def apply_mrope(x, positions3, sections, theta=10000.0):
    """Qwen2-VL multimodal RoPE.

    x (b, s, h, hd); positions3 (b, 3, s) = (temporal, height, width) ids.
    `sections` gives the number of (cos,sin) slots taken from each of the
    three position streams; sum(sections) == hd // 2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)  # (hd/2,)
    ang_all = positions3[..., None].astype(jnp.float32) * freqs       # (b,3,s,hd/2)
    parts, off = [], 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[:, i, :, off:off + sec])
        off += sec
    ang = jnp.concatenate(parts, axis=-1)                              # (b,s,hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape).astype(x.dtype)


def sinusoidal_embedding(positions, dim, max_scale=10000.0):
    """positions (b, s) -> (b, s, dim)."""
    half = dim // 2
    freqs = max_scale ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------- causal conv

def causal_conv1d(x, weight, bias):
    """Depthwise causal conv.  x (b, s, d); weight (k, d); bias (d)."""
    k = weight.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * weight[i] for i in range(k))
    return out + bias


def causal_conv1d_step(x_t, conv_state, weight, bias):
    """One decode step.  x_t (b, d); conv_state (b, k-1, d) past inputs."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (b,k,d)
    out = jnp.einsum("bkd,kd->bd", window, weight) + bias
    return out, window[:, 1:, :]
