"""RG-LRU recurrent block (recurrentgemma-9b hybrid family).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The block wraps the RG-LRU in the Griffin recurrent-block shape: two input
projections (signal + gelu gate), a short causal conv on the signal branch,
and an output projection.  Full-seq uses the same chunked associative scan
machinery as the SSM; decode is a one-step update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, causal_conv1d_step, dense_init

_C = 8.0  # temperature of the a_t parameterization (Griffin)


def rglru_init(key, cfg, dtype):
    r = cfg.rglru
    d = cfg.d_model
    w = r.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        "in_x": dense_init(ks[0], (d, w), dtype),
        "in_gate": dense_init(ks[1], (d, w), dtype),
        "conv_w": dense_init(ks[2], (r.conv_kernel, w), dtype,
                             fan_in=r.conv_kernel),
        "conv_b": jnp.zeros((w,), dtype),
        # block-diagonal gates (Griffin §2.4): gb blocks of (w/gb, w/gb)
        "w_a": dense_init(ks[3], (r.gate_blocks, w // r.gate_blocks,
                                  w // r.gate_blocks), dtype,
                          fan_in=w // r.gate_blocks),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(ks[4], (r.gate_blocks, w // r.gate_blocks,
                                  w // r.gate_blocks), dtype,
                          fan_in=w // r.gate_blocks),
        "b_i": jnp.zeros((w,), jnp.float32),
        # Lambda init so that a ~ U(0.9, 0.999) at r=1 (Griffin appendix)
        "Lambda": jnp.full((w,), 0.7, jnp.float32),
        "out": dense_init(ks[5], (w, d), dtype, fan_in=w),
    }


def _block_matmul(x, w_blocks):
    """x (..., w) @ block-diag(w_blocks (gb, w/gb, w/gb)) -> (..., w)."""
    gb, bw, _ = w_blocks.shape
    xb = x.reshape(x.shape[:-1] + (gb, bw))
    yb = jnp.einsum("...gb,gbc->...gc", xb, w_blocks)
    return yb.reshape(x.shape)


def _gates(p, xc):
    r = jax.nn.sigmoid(_block_matmul(xc, p["w_a"]).astype(jnp.float32)
                       + p["b_a"])
    i = jax.nn.sigmoid(_block_matmul(xc, p["w_i"]).astype(jnp.float32)
                       + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["Lambda"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    gated_x = beta * (i * xc.astype(jnp.float32))
    return a, gated_x


def linear_recurrence(a, bx, h0=None, chunk=64):
    """h_t = a_t h_{t-1} + bx_t over axis 1.  a, bx (b, s, w)."""
    b, s, w = a.shape
    pad = (-s) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    a_c = a.reshape(b, nc, chunk, w).transpose(1, 0, 2, 3)
    bx_c = bx.reshape(b, nc, chunk, w).transpose(1, 0, 2, 3)
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)

    def chunk_step(h, inp):
        ai, bi = inp
        def comb(l, r):
            return (l[0] * r[0], l[1] * r[0] + r[1])
        aa, hh = jax.lax.associative_scan(comb, (ai, bi), axis=1)
        hh = hh + aa * h[:, None]
        return hh[:, -1], hh

    # flash-style: recompute the within-chunk scan in the backward pass
    h_final, hs = jax.lax.scan(jax.checkpoint(chunk_step), h0, (a_c, bx_c))
    h = hs.transpose(1, 0, 2, 3).reshape(b, nc * chunk, w)
    return h[:, :s], h_final


def rglru_apply(p, x, cfg, constrain=None):
    """Full-sequence recurrent block.  x (b, s, d) -> (b, s, d)."""
    xi = x @ p["in_x"]
    gate = jax.nn.gelu(x @ p["in_gate"])
    xc = causal_conv1d(xi, p["conv_w"], p["conv_b"])
    if constrain is not None:
        xc = constrain(xc, "rnn_inner")
    a, bx = _gates(p, xc)
    h, _ = linear_recurrence(a, bx)
    y = h.astype(x.dtype) * gate
    return y @ p["out"]


def rglru_prefill(p, x, cfg, constrain=None):
    """Full-seq forward that also returns the decode cache."""
    xi = x @ p["in_x"]
    gate = jax.nn.gelu(x @ p["in_gate"])
    xc = causal_conv1d(xi, p["conv_w"], p["conv_b"])
    if constrain is not None:
        xc = constrain(xc, "rnn_inner")
    a, bx = _gates(p, xc)
    h, h_final = linear_recurrence(a, bx)
    y = h.astype(x.dtype) * gate
    k = cfg.rglru.conv_kernel
    conv_state = xi[:, -(k - 1):, :]
    pad = (k - 1) - conv_state.shape[1]
    if pad > 0:
        conv_state = jnp.pad(conv_state, ((0, 0), (pad, 0), (0, 0)))
    cache = {"h": h_final, "conv": conv_state.astype(x.dtype)}
    return y @ p["out"], cache


def init_rglru_cache(cfg, batch, dtype=jnp.float32):
    r = cfg.rglru
    w = r.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, r.conv_kernel - 1, w), dtype),
    }


def rglru_decode(p, x, cache, cfg):
    xi = x[:, 0] @ p["in_x"]
    gate = jax.nn.gelu(x[:, 0] @ p["in_gate"])
    xc, conv = causal_conv1d_step(xi, cache["conv"], p["conv_w"], p["conv_b"])
    a, bx = _gates(p, xc)
    h = a * cache["h"] + bx
    y = h.astype(x.dtype) * gate
    return (y @ p["out"])[:, None], {"h": h, "conv": conv}
