"""Mamba-1 selective-state-space block (falcon-mamba-7b family).

Full-sequence path uses a chunked associative scan (the pure-JAX twin /
oracle of ``repro.kernels.selective_scan``); decode is a single recurrent
state update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import causal_conv1d, causal_conv1d_step, dense_init


def mamba_init(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dt_rank = cfg.dt_rank
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, s.state_dim + 1, dtype=jnp.float32), (d_in, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (s.conv_kernel, d_in), dtype,
                             fan_in=s.conv_kernel),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * s.state_dim), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), dtype, fan_in=dt_rank),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.clip(np.random.RandomState(0).uniform(
                1e-3, 1e-1, d_in), 1e-4, None))), dtype),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, d), dtype, fan_in=d_in),
    }


def _ssm_inputs(p, xc, cfg):
    """xc (b, s, d_in) post-conv activations -> (dA, dBx, C) scan inputs."""
    s = cfg.ssm
    dt_rank = cfg.dt_rank
    proj = xc @ p["x_proj"]
    dt, B, C = jnp.split(proj, [dt_rank, dt_rank + s.state_dim], axis=-1)
    dt = jax.nn.softplus((dt @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (b,s,d_in)
    A = -jnp.exp(p["A_log"])                                   # (d_in, n)
    dA = jnp.exp(dt[..., None] * A)                            # (b,s,d_in,n)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * B.astype(jnp.float32)[:, :, None, :]
    if getattr(cfg, "ssm_scan_bf16", False):
        # stream the scan inputs at bf16 (HBM traffic); the chunk scan
        # still combines in f32 — mirrors the Pallas kernel's HBM->VMEM
        # staging (§Perf)
        return (dA.astype(jnp.bfloat16), dBx.astype(jnp.bfloat16),
                C.astype(jnp.bfloat16))
    return dA, dBx, C.astype(jnp.float32)


def selective_scan(dA, dBx, C, h0=None, chunk=64):
    """Associative scan of h_t = dA_t * h_{t-1} + dBx_t ; y_t = <h_t, C_t>.

    dA, dBx (b, s, d_in, n); C (b, s, n).  Chunked: outer lax.scan carries the
    state between chunks; inner associative_scan parallelizes within a chunk.
    Returns y (b, s, d_in) and final state (b, d_in, n).
    """
    b, s, d_in, n = dA.shape
    pad = (-s) % chunk
    if pad:
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
        dBx = jnp.pad(dBx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    dA_c = dA.reshape(b, nc, chunk, d_in, n).transpose(1, 0, 2, 3, 4)
    dBx_c = dBx.reshape(b, nc, chunk, d_in, n).transpose(1, 0, 2, 3, 4)
    C_c = C.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    if h0 is None:
        h0 = jnp.zeros((b, d_in, n), jnp.float32)

    def chunk_step(h, inp):
        a, bx, c = inp                                       # (b,chunk,d_in,n)
        a, bx, c = (a.astype(jnp.float32), bx.astype(jnp.float32),
                    c.astype(jnp.float32))
        def comb(l, r):
            return (l[0] * r[0], l[1] * r[0] + r[1])
        aa, hh = jax.lax.associative_scan(comb, (a, bx), axis=1)
        hh = hh + aa * h[:, None]                            # inject carry
        y = jnp.einsum("bcdn,bcn->bcd", hh, c)
        return hh[:, -1], y

    # recompute the within-chunk associative scan in backward instead of
    # saving its O(log chunk) intermediate levels (flash-style memory)
    h_final, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0,
                               (dA_c, dBx_c, C_c))
    y = ys.transpose(1, 0, 2, 3).reshape(b, nc * chunk, d_in)
    return y[:, :s], h_final


def mamba_apply(p, x, cfg, constrain=None):
    """Full-sequence mamba block.  x (b, s, d) -> (b, s, d)."""
    d_in = cfg.ssm.expand * cfg.d_model
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, [d_in], axis=-1)
    xc = jax.nn.silu(causal_conv1d(xi, p["conv_w"], p["conv_b"]))
    if constrain is not None:
        xc = constrain(xc, "ssm_inner")
    dA, dBx, C = _ssm_inputs(p, xc, cfg)
    y, _ = selective_scan(dA, dBx, C)
    y = y + xc.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_prefill(p, x, cfg, constrain=None):
    """Full-seq forward that also returns the decode cache (state + conv)."""
    d_in = cfg.ssm.expand * cfg.d_model
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, [d_in], axis=-1)
    xc = jax.nn.silu(causal_conv1d(xi, p["conv_w"], p["conv_b"]))
    if constrain is not None:
        xc = constrain(xc, "ssm_inner")
    dA, dBx, C = _ssm_inputs(p, xc, cfg)
    y, h_final = selective_scan(dA, dBx, C)
    y = y + xc.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    k = cfg.ssm.conv_kernel
    conv_state = xi[:, -(k - 1):, :]
    pad = (k - 1) - conv_state.shape[1]
    if pad > 0:
        conv_state = jnp.pad(conv_state, ((0, 0), (pad, 0), (0, 0)))
    cache = {"h": h_final, "conv": conv_state.astype(x.dtype)}
    return y @ p["out_proj"], cache


def init_mamba_cache(cfg, batch, dtype=jnp.float32):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, d_in, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_kernel - 1, d_in), dtype),
    }


def mamba_decode(p, x, cache, cfg):
    """One-token decode.  x (b, 1, d)."""
    d_in = cfg.ssm.expand * cfg.d_model
    xz = x[:, 0] @ p["in_proj"]
    xi, z = jnp.split(xz, [d_in], axis=-1)
    xc, conv = causal_conv1d_step(xi, cache["conv"], p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    dA, dBx, C = _ssm_inputs(p, xc[:, None], cfg)
    h = dA[:, 0] * cache["h"] + dBx[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, C[:, 0])
    y = y + xc.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return (y @ p["out_proj"])[:, None], {"h": h, "conv": conv}
