"""Pytree checkpointing: msgpack index + raw .npy shards, no deps.

Works for params, optimizer states (NamedTuples flattened via
jax.tree_util) and the MAB/DASO policy states.  Arrays are gathered to
host; save/restore round-trips bit-exactly (tested).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]

    def name(path):
        out = []
        for k in path:
            out.append(str(getattr(k, "key", getattr(k, "idx", k))))
        return "/".join(out)

    return [(name(p), leaf) for p, leaf in paths], treedef


def save_checkpoint(directory: str, tree, step: int = 0):
    os.makedirs(directory, exist_ok=True)
    named, treedef = _paths(tree)
    index = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(named):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(directory, fname), arr)
        index["leaves"].append({"name": name, "file": fname,
                                "dtype": str(arr.dtype),
                                "shape": list(arr.shape)})
    index["treedef"] = str(treedef)
    with open(os.path.join(directory, "index.json"), "w") as f:
        json.dump(index, f, indent=1)


def restore_checkpoint(directory: str, like_tree):
    """Restores into the structure of ``like_tree`` (shape-checked)."""
    with open(os.path.join(directory, "index.json")) as f:
        index = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten(like_tree)
    assert len(flat) == len(index["leaves"]), \
        f"leaf count mismatch {len(flat)} vs {len(index['leaves'])}"
    leaves = []
    for meta, like in zip(index["leaves"], flat):
        arr = np.load(os.path.join(directory, meta["file"]))
        assert list(arr.shape) == list(np.shape(like)), \
            f"{meta['name']}: {arr.shape} vs {np.shape(like)}"
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), index["step"]
