"""Optimizers implemented from scratch in JAX: AdamW and Adafactor.

No optax dependency — the framework owns its optimizer substrate.  Both
expose the same (init, update) pair operating on arbitrary pytrees, plus
global-norm clipping and linear-warmup-cosine schedules.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), n


def warmup_cosine(step, peak_lr, warmup_steps=100, total_steps=10000,
                  min_ratio=0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * jnp.minimum(1.0, (step + 1) / warmup_steps)
    frac = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps),
                    0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup_steps, warm, cos)


# ------------------------------------------------------------------ AdamW

class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: object
    v: object


def adamw_init(params, dtype=jnp.float32):
    z = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(z, params),
                      v=jax.tree.map(z, params))


def adamw_update(grads, state, params, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                     state.m, grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(
        g.astype(v.dtype)), state.v, grads)
    bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        u = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(m.dtype)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v)


# --------------------------------------------------------------- Adafactor

class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: object     # row statistics (or full v for <2D leaves)
    vc: object     # col statistics (None for <2D leaves)


def _factored(p):
    return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2


def adafactor_init(params):
    def vr(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vr, params),
                          vc=jax.tree.map(vc, params))


def adafactor_update(grads, state, params, lr, decay_pow=0.8, eps=1e-30,
                     clip_threshold=1.0, weight_decay=0.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-decay_pow)

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if _factored(p):
            vr_n = beta2 * vr + (1 - beta2) * g2.mean(-1)
            vc_n = beta2 * vc + (1 - beta2) * g2.mean(-2)
            denom = (vr_n / jnp.maximum(vr_n.mean(-1, keepdims=True), eps))[..., None] * vc_n[..., None, :]
            u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
        else:
            vr_n, vc_n = beta2 * vr + (1 - beta2) * g2, vc
            u = g * jax.lax.rsqrt(jnp.maximum(vr_n, eps))
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        new_p = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), vr_n, vc_n

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_vr = tdef.flatten_up_to(state.vr)
    flat_vc = tdef.flatten_up_to(state.vc)
    out = [upd(p, g, vr, vc) for p, g, vr, vc in
           zip(flat_p, flat_g, flat_vr, flat_vc)]
    new_params = tdef.unflatten([o[0] for o in out])
    vr = tdef.unflatten([o[1] for o in out])
    vc = tdef.unflatten([o[2] for o in out])
    return new_params, AdafactorState(step=step, vr=vr, vc=vc)


def make_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(name)
