"""Pallas TPU selective scan (Mamba-1 SSM recurrence).

    h_t = dA_t * h_{t-1} + dBx_t          (elementwise over (d_in, n))
    y_t = <h_t, C_t>                      (contract over n)

TPU adaptation: the recurrence is bandwidth-bound, so the kernel streams
seq-chunks of (dA, dBx, C) HBM→VMEM while the (bd, n) state lives in VMEM
scratch persisting across the innermost seq-chunk grid dimension; the
channel dimension is tiled in lane-aligned blocks of 128.  Within a chunk
the time loop is a fori over VMEM-resident data (VPU work, no HBM traffic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dA_ref, dBx_ref, C_ref, y_ref, h_ref, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    dA = dA_ref[0].astype(jnp.float32)      # (chunk, bd, n)
    dBx = dBx_ref[0].astype(jnp.float32)
    C = C_ref[0].astype(jnp.float32)        # (chunk, n)

    def step(t, carry):
        h, ys = carry
        h = dA[t] * h + dBx[t]
        y = jnp.sum(h * C[t][None, :], axis=-1)   # (bd,)
        ys = jax.lax.dynamic_update_index_in_dim(ys, y, t, 0)
        return h, ys

    ys0 = jnp.zeros((chunk,) + h_ref.shape[:1], jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h_ref[...], ys0))
    h_ref[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "d_block", "interpret"))
def selective_scan(dA, dBx, C, chunk=128, d_block=128, interpret=False):
    """dA, dBx (b, s, d_in, n); C (b, s, n) -> y (b, s, d_in) float32."""
    b, s, d_in, n = dA.shape
    chunk = min(chunk, s)
    d_block = min(d_block, d_in)
    ns = -(-s // chunk)
    nd = -(-d_in // d_block)
    ps, pd = ns * chunk - s, nd * d_block - d_in
    if ps or pd:
        dA = jnp.pad(dA, ((0, 0), (0, ps), (0, pd), (0, 0)),
                     constant_values=1.0)
        dBx = jnp.pad(dBx, ((0, 0), (0, ps), (0, pd), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, ps), (0, 0)))
    grid = (b, nd, ns)
    y = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_block, n),
                         lambda bi, di, ci: (bi, ci, di, 0)),
            pl.BlockSpec((1, chunk, d_block, n),
                         lambda bi, di, ci: (bi, ci, di, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block),
                               lambda bi, di, ci: (bi, ci, di)),
        out_shape=jax.ShapeDtypeStruct((b, ns * chunk, nd * d_block),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        interpret=interpret,
    )(dA, dBx, C)
    return y[:, :s, :d_in]
