"""Pallas edge-substep physics kernel (SplitPlace interval program).

Fuses one scheduling interval's substep loop — execute/advance physics
under MIPS sharing and swap slowdown, chain activation transfers under
mobility-modulated NIC bandwidth, and the eq. 13–16 metric accumulation
over padded slots — into a single grid-free kernel.  The (K, F) slot
store plus the (n,) cluster rows total a few hundred KB, so every
operand fits in VMEM as one full-array block: the interval-static
hoists (placement one-hots, pairwise chain bandwidth, decision one-hot)
are computed once on loaded values, and the substep loop is a
``fori_loop`` over VMEM-resident data with zero HBM traffic between
substeps — on XLA:CPU the same fusion runs via ``interpret=True``
(the driver's ``substep_impl="pallas"`` switch), where the kernel
traces into the surrounding jit instead of bouncing ~10 small tuned
ops per substep through the scheduler.

Validated against the pure-jnp oracle ``repro.kernels.ref
.edge_substep_ref`` (rtol=1e-12 on the float64 carries) and — through
the driver switch — against the incremental-census XLA formulation,
the EdgeSim differential fuzzer, and the golden fixtures.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: input/output operand order of the fused kernel (carries first, then
#: interval-static per-task/per-fragment channels, then cluster rows)
CARRY_NAMES = ("instr", "done", "transfer", "stage", "task_done", "resp",
               "now", "metrics")
STATIC_NAMES = ("worker", "ram_task", "out_bytes", "nfrag", "chain",
                "placed", "sla", "arrival", "acc_t", "wait_s", "decision",
                "bw_mult", "mips", "cap", "net_bw")
OUT_NAMES = CARRY_NAMES + ("busy", "pwt_delta")


def _kernel(instr_ref, done_ref, transfer_ref, stage_ref, task_done_ref,
            resp_ref, now_ref, metrics_ref, worker_ref, ram_task_ref,
            out_bytes_ref, nfrag_ref, chain_ref, placed_ref, sla_ref,
            arrival_ref, acc_t_ref, wait_s_ref, decision_ref, bw_mult_ref,
            mips_ref, cap_ref, net_bw_ref, o_instr, o_done, o_transfer,
            o_stage, o_task_done, o_resp, o_now, o_metrics, o_busy,
            o_pwt, *, substeps, dt, swap_slowdown, nic_cap):
    worker = worker_ref[...]
    ram_task = ram_task_ref[...]
    out_bytes = out_bytes_ref[...]
    nfrag = nfrag_ref[...]
    chain = chain_ref[...]
    placed = placed_ref[...]
    sla = sla_ref[...]
    arrival = arrival_ref[...]
    acc_t = acc_t_ref[...]
    wait_s = wait_s_ref[...]
    mips, cap = mips_ref[...], cap_ref[...]
    net_bw, bw_mult = net_bw_ref[...], bw_mult_ref[...]

    K, F = worker.shape
    n = mips.shape[0]
    f8 = jnp.float64

    # ---- interval-static hoists (once per kernel, VMEM-resident)
    fidx = jnp.arange(F, dtype=jnp.int32)[None, :]
    wsafe = jnp.clip(worker, 0, n - 1)
    chain_f = chain[:, None]
    placed_f = placed[:, None] & (worker >= 0)
    holdable = worker >= 0
    chactive = chain & placed & ~task_done_ref[...]
    kfn32 = (wsafe[:, :, None] == jnp.arange(n)).astype(jnp.float32)
    mips_f = mips[wsafe]
    doh = (jnp.clip(decision_ref[...], 0, 2)[:, None]
           == jnp.arange(3)).astype(f8)
    not_chain_f = ~chain_f
    arange_n = jnp.arange(n)
    ones_k = jnp.ones((K,))
    dual_idx = jnp.concatenate([wsafe.ravel(), wsafe.ravel() + n])
    hand_static = chain_f & (fidx < nfrag[:, None] - 1)
    out_r = jnp.concatenate([jnp.zeros((K, 1)), out_bytes[:, :-1]], axis=1)
    w_prev = jnp.clip(jnp.roll(worker, 1, axis=1), 0, n - 1)
    bw_pair = jnp.minimum(nic_cap, jnp.minimum(net_bw[w_prev] / 100.0,
                                               net_bw[wsafe] / 100.0))
    bw_pair = bw_pair * jnp.minimum(bw_mult[w_prev], bw_mult[wsafe])

    def census(mask_f):
        return jnp.einsum("kf,kfn->kn", mask_f.astype(jnp.float32), kfn32)

    # ---- the substep loop: pure VPU work on the VMEM-resident carry
    def body(_, carry):
        instr, done, transfer, stage, task_done, now_s, busy, m, resp_rec \
            = carry
        notdone = ~done
        cnt = census(notdone & holdable & not_chain_f)
        is_stage = fidx == stage[:, None]
        tle = (transfer <= 0.0) & is_stage
        runnable = (not_chain_f | tle) & placed_f & notdone
        holds = (not_chain_f | is_stage) & holdable & notdone
        stage_ch = jnp.take_along_axis(
            jnp.stack([wsafe.astype(f8), transfer, bw_pair,
                       runnable.astype(f8), holds.astype(f8)]),
            stage[None, :, None].astype(jnp.int32), axis=2)[:, :, 0]
        w_stage = stage_ch[0].astype(jnp.int32)
        cur_tl, bw_s = stage_ch[1], stage_ch[2]
        r_ch = (stage_ch[3] > 0.5) & chain
        h_ch = (stage_ch[4] > 0.5) & chain
        ohs = w_stage[:, None] == arange_n
        nc_lr = jnp.stack([ones_k, ram_task]) @ cnt.astype(f8)
        ch_lr = jnp.stack([r_ch.astype(f8),
                           jnp.where(h_ch, ram_task, 0.0)]) \
            @ ohs.astype(f8)
        load = nc_lr[0] + ch_lr[0]
        ram_load = nc_lr[1] + ch_lr[1]
        swap = ram_load > cap
        busy = busy + (load > 0) * dt
        lf_sw = jnp.take(jnp.concatenate([load, swap.astype(f8)]),
                         dual_idx).reshape(2, K, F)
        load_f, swap_f = lf_sw[0], lf_sw[1] > 0.5
        rate = mips_f / jnp.maximum(load_f, 1.0)
        rate = jnp.where(swap_f, rate * swap_slowdown, rate)
        instr = instr - jnp.where(runnable, rate * dt, 0.0)
        newly = runnable & (instr <= 0.0)
        done = done | newly
        hand = newly & hand_static
        hand_r = jnp.concatenate(
            [jnp.zeros((K, 1), bool), hand[:, :-1]], axis=1)
        transfer = jnp.where(hand_r, out_r, transfer)
        newfin = jnp.all(done, axis=1) & ~task_done
        task_done = task_done | newfin
        resp_t = now_s - arrival
        resp_rec = jnp.where(newfin, resp_t, resp_rec)
        finf = newfin.astype(f8)
        mcols = jnp.stack(
            [ones_k, resp_t, (resp_t > sla).astype(f8), acc_t,
             ((resp_t <= sla) + acc_t) / 2.0, wait_s,
             doh[:, 0], doh[:, 1], doh[:, 2]], axis=1)
        m = m + finf @ mcols
        s = stage
        cond = chactive & (s > 0) & (cur_tl > 0.0)
        transfer = transfer - jnp.where(
            cond, bw_s * 1e6 * dt, 0.0)[:, None] * is_stage
        done_s = jnp.take_along_axis(done, s[:, None], axis=1)[:, 0]
        adv = chactive & done_s & (s < nfrag - 1)
        stage = stage + adv.astype(jnp.int32)
        now_s = now_s + dt
        return (instr, done, transfer, stage, task_done, now_s, busy, m,
                resp_rec)

    done0 = done_ref[...]
    carry = (instr_ref[...], done0, transfer_ref[...], stage_ref[...],
             task_done_ref[...], now_ref[0], jnp.zeros((n,)),
             metrics_ref[...], resp_ref[...])
    (instr, done, transfer, stage, task_done, now_s, busy, m, resp_rec) \
        = jax.lax.fori_loop(0, substeps, body, carry)
    o_instr[...] = instr
    o_done[...] = done
    o_transfer[...] = transfer
    o_stage[...] = stage
    o_task_done[...] = task_done
    o_resp[...] = resp_rec
    o_now[0] = now_s
    o_metrics[...] = m
    o_busy[...] = busy
    o_pwt[...] = jnp.sum(census(done & ~done0), axis=0).astype(f8)


def edge_substep(instr, done, transfer, stage, task_done, resp, now,
                 metrics, worker, ram_task, out_bytes, nfrag, chain,
                 placed, sla, arrival, acc_t, wait_s, decision, bw_mult,
                 mips, cap, net_bw, *, substeps, dt, swap_slowdown,
                 nic_cap, interpret=True):
    """One interval of fused substep physics; see ``_kernel`` and the
    module docstring.  Argument order is ``CARRY_NAMES + STATIC_NAMES``;
    returns the ``OUT_NAMES`` tuple (updated carries + per-worker busy
    seconds and completion census).  ``interpret=True`` is the CPU
    execution mode; the call batches transparently under ``vmap`` (the
    batching rule prepends a grid axis), which is how the grid driver
    runs one kernel instance per trace cell."""
    n = mips.shape[0]
    out_shape = (
        jax.ShapeDtypeStruct(instr.shape, instr.dtype),
        jax.ShapeDtypeStruct(done.shape, done.dtype),
        jax.ShapeDtypeStruct(transfer.shape, transfer.dtype),
        jax.ShapeDtypeStruct(stage.shape, stage.dtype),
        jax.ShapeDtypeStruct(task_done.shape, task_done.dtype),
        jax.ShapeDtypeStruct(resp.shape, resp.dtype),
        jax.ShapeDtypeStruct(now.shape, now.dtype),
        jax.ShapeDtypeStruct(metrics.shape, metrics.dtype),
        jax.ShapeDtypeStruct((n,), jnp.float64),
        jax.ShapeDtypeStruct((n,), jnp.float64),
    )
    return pl.pallas_call(
        functools.partial(_kernel, substeps=substeps, dt=dt,
                          swap_slowdown=swap_slowdown, nic_cap=nic_cap),
        out_shape=out_shape,
        interpret=interpret,
    )(instr, done, transfer, stage, task_done, resp, now, metrics,
      worker, ram_task, out_bytes, nfrag, chain, placed, sla, arrival,
      acc_t, wait_s, decision, bw_mult, mips, cap, net_bw)
