"""Jit'd public wrappers for the Pallas kernels.

On TPU the compiled kernels run natively; on CPU (this container) they run
in interpret mode so every call is still exercised end-to-end.  Callers use
these entry points; models fall back to the jnp twins for SPMD tracing
(Pallas-TPU ops do not lower on the CPU dry-run backend).
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.moe_route import moe_route as _route
from repro.kernels.rglru_scan import rglru_scan as _rglru
from repro.kernels.selective_scan import selective_scan as _scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, causal=True, window=0, q_block=128,
                    kv_block=128):
    return _flash(q, k, v, causal=causal, window=window, q_block=q_block,
                  kv_block=kv_block, interpret=_interpret())


def selective_scan(dA, dBx, C, chunk=128, d_block=128):
    return _scan(dA, dBx, C, chunk=chunk, d_block=d_block,
                 interpret=_interpret())


def rglru_scan(a, bx, chunk=128, w_block=512):
    return _rglru(a, bx, chunk=chunk, w_block=w_block,
                  interpret=_interpret())


def moe_route(logits, top_k, block=256):
    return _route(logits, top_k, block=block, interpret=_interpret())
