"""Pallas TPU fused MoE router: softmax → top-k → capacity slot assignment.

One pass over token blocks produces, per (token, choice):
  * the expert id and normalized gate weight,
  * the slot index within the expert's capacity buffer (running per-expert
    counters live in VMEM scratch and persist across the token-block grid,
    so slot assignment is globally consistent without a host round trip).

This fuses what the jnp path does with softmax + top_k + a (S·k, E)
one-hot cumsum — the cumsum is the memory hog the kernel eliminates
(O(E) state instead of O(S·k·E) traffic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(logits_ref, eid_ref, gate_ref, slot_ref, count_ref, *,
            top_k, block):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    logits = logits_ref[...].astype(jnp.float32)          # (block, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # iterative top-k (k argmax+mask passes keep everything in VMEM)
    def pick(j, carry):
        p, eids, gates = carry
        idx = jnp.argmax(p, axis=-1)                      # (block,)
        val = jnp.max(p, axis=-1)
        eids = jax.lax.dynamic_update_index_in_dim(eids, idx.astype(jnp.int32), j, 1)
        gates = jax.lax.dynamic_update_index_in_dim(gates, val, j, 1)
        p = p * (1.0 - jax.nn.one_hot(idx, p.shape[-1], dtype=p.dtype))
        return p, eids, gates

    eids0 = jnp.zeros((block, top_k), jnp.int32)
    gates0 = jnp.zeros((block, top_k), jnp.float32)
    _, eids, gates = jax.lax.fori_loop(0, top_k, pick, (probs, eids0, gates0))
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # sequential slot assignment against persistent per-expert counters
    def assign(i, carry):
        counts, slots = carry
        t, j = i // top_k, i % top_k
        e = eids[t, j]
        s = counts[e]
        counts = counts.at[e].add(1)
        slots = slots.at[t, j].set(s)
        return counts, slots

    slots0 = jnp.zeros((block, top_k), jnp.int32)
    counts, slots = jax.lax.fori_loop(0, block * top_k, assign,
                                      (count_ref[...], slots0))
    count_ref[...] = counts
    eid_ref[...] = eids
    gate_ref[...] = gates
    slot_ref[...] = slots


@functools.partial(jax.jit, static_argnames=("top_k", "block", "interpret"))
def moe_route(logits, top_k, block=256, interpret=False):
    """logits (S, E) -> (expert_id (S,k), gate (S,k), slot (S,k))."""
    S, E = logits.shape
    block = min(block, S)
    nb = -(-S // block)
    pad = nb * block - S
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)), constant_values=-1e30)
    out_shapes = (
        jax.ShapeDtypeStruct((nb * block, top_k), jnp.int32),
        jax.ShapeDtypeStruct((nb * block, top_k), jnp.float32),
        jax.ShapeDtypeStruct((nb * block, top_k), jnp.int32),
    )
    spec = pl.BlockSpec((block, top_k), lambda ti: (ti, 0))
    eid, gate, slot = pl.pallas_call(
        functools.partial(_kernel, top_k=top_k, block=block),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block, E), lambda ti: (ti, 0))],
        out_specs=(spec, spec, spec),
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((E,), jnp.int32)],
        interpret=interpret,
    )(logits)
    return eid[:S], gate[:S], slot[:S]
