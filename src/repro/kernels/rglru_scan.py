"""Pallas TPU RG-LRU linear recurrence:  h_t = a_t h_{t-1} + bx_t.

Same streaming structure as the selective scan but the state is a flat
(width,) vector — pure VPU elementwise work, so the channel tile is a
full (8, 128)-register-aligned 128 lanes and the kernel is purely
HBM-bandwidth-bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, bx_ref, h_out_ref, h_ref, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)        # (chunk, bw)
    bx = bx_ref[0].astype(jnp.float32)

    def step(t, carry):
        h, hs = carry
        h = a[t] * h + bx[t]
        hs = jax.lax.dynamic_update_index_in_dim(hs, h, t, 0)
        return h, hs

    hs0 = jnp.zeros((chunk,) + h_ref.shape, jnp.float32)
    h, hs = jax.lax.fori_loop(0, chunk, step, (h_ref[...], hs0))
    h_ref[...] = h
    h_out_ref[0] = hs.astype(h_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "w_block", "interpret"))
def rglru_scan(a, bx, chunk=128, w_block=512, interpret=False):
    """a, bx (b, s, w) -> h (b, s, w) float32."""
    b, s, w = a.shape
    chunk = min(chunk, s)
    w_block = min(w_block, w)
    ns = -(-s // chunk)
    nw = -(-w // w_block)
    ps, pw = ns * chunk - s, nw * w_block - w
    if ps or pw:
        a = jnp.pad(a, ((0, 0), (0, ps), (0, pw)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, ps), (0, pw)))
    grid = (b, nw, ns)
    h = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, w_block), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, chunk, w_block), lambda bi, wi, ci: (bi, ci, wi)),
        ],
        out_specs=pl.BlockSpec((1, chunk, w_block),
                               lambda bi, wi, ci: (bi, ci, wi)),
        out_shape=jax.ShapeDtypeStruct((b, ns * chunk, nw * w_block),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((w_block,), jnp.float32)],
        interpret=interpret,
    )(a, bx)
    return h[:, :s, :w]
