"""Pallas TPU flash attention (causal / sliding-window, GQA).

TPU adaptation notes (vs the CUDA flash-attention the literature targets):
  * tiles are MXU-aligned (q_block × head_dim and kv_block × head_dim in
    multiples of 128 where shapes allow) and staged HBM→VMEM by BlockSpec;
  * the online-softmax running max/denominator/accumulator live in VMEM
    scratch that persists across the innermost (kv) grid dimension — the
    TPU sequential-grid analogue of a CUDA persistent CTA loop;
  * GQA is expressed in the grid (b, kv_head, group, nq, nk) so K/V blocks
    are fetched once per kv head, not per q head.

Validated in interpret mode against ``repro.kernels.ref.attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            kv_block, q_block, sk, causal, window, scale):
    qi = pl.program_id(3)
    ki = pl.program_id(4)
    nk = pl.num_programs(4)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, 0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < sk
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0, 0] = (acc_ref[...] /
                          jnp.maximum(l_ref[...], 1e-30)[:, None]
                          ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block", "interpret"))
def flash_attention(q, k, v, causal=True, window=0, q_block=128,
                    kv_block=128, interpret=False):
    """q (b, sq, h, hd); k, v (b, sk, kvh, hd) -> (b, sq, h, hd)."""
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    nq = -(-sq // q_block)
    nk = -(-sk // kv_block)
    pq, pk = nq * q_block - sq, nk * kv_block - sk
    qr = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kr = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vr = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    # (b, kvh, g, sq, hd) / (b, kvh, sk, hd)
    qr = qr.reshape(b, nq * q_block, kvh, g, hd).transpose(0, 2, 3, 1, 4)
    kr = kr.transpose(0, 2, 1, 3)
    vr = vr.transpose(0, 2, 1, 3)
    grid = (b, kvh, g, nq, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, kv_block=kv_block, q_block=q_block,
                          sk=sk, causal=causal, window=window,
                          scale=hd ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, q_block, hd),
                         lambda bi, ki, gi, qi, kj: (bi, ki, gi, qi, 0)),
            pl.BlockSpec((1, 1, kv_block, hd),
                         lambda bi, ki, gi, qi, kj: (bi, ki, kj, 0)),
            pl.BlockSpec((1, 1, kv_block, hd),
                         lambda bi, ki, gi, qi, kj: (bi, ki, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, q_block, hd),
                               lambda bi, ki, gi, qi, kj: (bi, ki, gi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, nq * q_block, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, nq * q_block, h, hd)
    return out[:, :sq]
