"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors the kernel's contract exactly and is used by the
per-kernel shape/dtype sweep tests (assert_allclose, interpret=True).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, causal=True, window=0):
    """q (b, sq, h, hd); k, v (b, sk, kvh, hd) -> (b, sq, h, hd)."""
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def selective_scan_ref(dA, dBx, C):
    """Sequential reference of h_t = dA_t h_{t-1} + dBx_t; y_t = <h_t, C_t>."""
    b, s, d_in, n = dA.shape

    def step(h, inp):
        a, bx, c = inp
        h = a * h + bx
        return h, jnp.einsum("bdn,bn->bd", h, c)

    h0 = jnp.zeros((b, d_in, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (dA.astype(jnp.float32).swapaxes(0, 1),
                          dBx.astype(jnp.float32).swapaxes(0, 1),
                          C.astype(jnp.float32).swapaxes(0, 1)))
    return ys.swapaxes(0, 1)


def rglru_scan_ref(a, bx):
    """Sequential reference of h_t = a_t h_{t-1} + bx_t (elementwise)."""
    b, s, w = a.shape

    def step(h, inp):
        ai, bi = inp
        h = ai * h + bi
        return h, h

    h0 = jnp.zeros((b, w), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a.astype(jnp.float32).swapaxes(0, 1),
                                    bx.astype(jnp.float32).swapaxes(0, 1)))
    return hs.swapaxes(0, 1)


def moe_route_ref(logits, top_k):
    """softmax -> top-k -> first-come slot assignment (token order)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, eids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    S, E = logits.shape
    flat = jax.nn.one_hot(eids.reshape(-1), E, dtype=jnp.int32)
    pos = (jnp.cumsum(flat, axis=0) - 1) * flat
    slots = pos.sum(-1).reshape(S, top_k)
    return eids.astype(jnp.int32), gates, slots.astype(jnp.int32)
