"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors the kernel's contract exactly and is used by the
per-kernel shape/dtype sweep tests (assert_allclose, interpret=True).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, causal=True, window=0):
    """q (b, sq, h, hd); k, v (b, sk, kvh, hd) -> (b, sq, h, hd)."""
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def selective_scan_ref(dA, dBx, C):
    """Sequential reference of h_t = dA_t h_{t-1} + dBx_t; y_t = <h_t, C_t>."""
    b, s, d_in, n = dA.shape

    def step(h, inp):
        a, bx, c = inp
        h = a * h + bx
        return h, jnp.einsum("bdn,bn->bd", h, c)

    h0 = jnp.zeros((b, d_in, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (dA.astype(jnp.float32).swapaxes(0, 1),
                          dBx.astype(jnp.float32).swapaxes(0, 1),
                          C.astype(jnp.float32).swapaxes(0, 1)))
    return ys.swapaxes(0, 1)


def rglru_scan_ref(a, bx):
    """Sequential reference of h_t = a_t h_{t-1} + bx_t (elementwise)."""
    b, s, w = a.shape

    def step(h, inp):
        ai, bi = inp
        h = ai * h + bi
        return h, h

    h0 = jnp.zeros((b, w), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a.astype(jnp.float32).swapaxes(0, 1),
                                    bx.astype(jnp.float32).swapaxes(0, 1)))
    return hs.swapaxes(0, 1)


def edge_substep_ref(instr, done, transfer, stage, task_done, resp, now,
                     metrics, worker, ram_task, out_bytes, nfrag, chain,
                     placed, sla, arrival, acc_t, wait_s, decision,
                     bw_mult, mips, cap, net_bw, *, substeps, dt,
                     swap_slowdown, nic_cap):
    """Pure-jnp oracle of the fused edge-substep physics kernel.

    One scheduling interval of SplitPlace substep physics (MIPS sharing,
    swap slowdown, chain activation transfers, eq. 13–16 metric
    accumulation) over the padded (K, F) slot store — the correctness
    ground truth for ``repro.kernels.edge_substep``.  Unlike the
    incremental-census production path in ``env/jaxsim/kernels
    .run_substeps`` this recomputes the per-(task, worker) fragment
    census densely every substep; the counts are small integers exact in
    float32, so both formulations agree bitwise on the census and to
    float64 rounding everywhere else.

    Inputs: float64 carries ``instr``/``transfer`` (K, F), bool
    ``done`` (K, F) / ``task_done`` (K,), i32 ``stage`` (K,), float64
    per-task channels (K,), the interval-static placement ``worker``
    (K, F) i32, per-worker cluster rows (n,), ``now`` and the packed
    9-column ``metrics`` accumulator as (1,) / (9,) float64.  Returns
    the updated ``(instr, done, transfer, stage, task_done, resp, now,
    metrics, busy, pwt_delta)`` tuple with per-worker busy seconds and
    the interval's per-worker completion census.
    """
    K, F = worker.shape
    n = mips.shape[0]
    f8 = jnp.float64
    fidx = jnp.arange(F, dtype=jnp.int32)[None, :]
    wsafe = jnp.clip(worker, 0, n - 1)
    chain_f = chain[:, None]
    placed_f = placed[:, None] & (worker >= 0)
    holdable = worker >= 0
    chactive = chain & placed & ~task_done
    kfn32 = (wsafe[:, :, None] == jnp.arange(n)).astype(jnp.float32)
    mips_f = mips[wsafe]
    doh = (jnp.clip(decision, 0, 2)[:, None]
           == jnp.arange(3)).astype(f8)                   # (K, 3)
    not_chain_f = ~chain_f
    arange_n = jnp.arange(n)
    ones_k = jnp.ones((K,))
    dual_idx = jnp.concatenate([wsafe.ravel(), wsafe.ravel() + n])
    hand_static = chain_f & (fidx < nfrag[:, None] - 1)
    out_r = jnp.concatenate([jnp.zeros((K, 1)), out_bytes[:, :-1]], axis=1)
    w_prev = jnp.clip(jnp.roll(worker, 1, axis=1), 0, n - 1)
    bw_pair = jnp.minimum(nic_cap, jnp.minimum(net_bw[w_prev] / 100.0,
                                               net_bw[wsafe] / 100.0))
    bw_pair = bw_pair * jnp.minimum(bw_mult[w_prev], bw_mult[wsafe])

    def census(mask_f):
        return jnp.einsum("kf,kfn->kn", mask_f.astype(jnp.float32), kfn32)

    def body(carry, _):
        instr, done, transfer, stage, task_done, now_s, busy, m, resp_rec \
            = carry
        notdone = ~done
        cnt = census(notdone & holdable & not_chain_f)
        is_stage = fidx == stage[:, None]
        tle = (transfer <= 0.0) & is_stage
        runnable = (not_chain_f | tle) & placed_f & notdone
        holds = (not_chain_f | is_stage) & holdable & notdone
        stage_ch = jnp.take_along_axis(
            jnp.stack([wsafe.astype(f8), transfer, bw_pair,
                       runnable.astype(f8), holds.astype(f8)]),
            stage[None, :, None].astype(jnp.int32), axis=2)[:, :, 0]
        w_stage = stage_ch[0].astype(jnp.int32)
        cur_tl, bw_s = stage_ch[1], stage_ch[2]
        r_ch = (stage_ch[3] > 0.5) & chain
        h_ch = (stage_ch[4] > 0.5) & chain
        ohs = w_stage[:, None] == arange_n
        nc_lr = jnp.stack([ones_k, ram_task]) @ cnt.astype(f8)
        ch_lr = jnp.stack([r_ch.astype(f8),
                           jnp.where(h_ch, ram_task, 0.0)]) \
            @ ohs.astype(f8)
        load = nc_lr[0] + ch_lr[0]
        ram_load = nc_lr[1] + ch_lr[1]
        swap = ram_load > cap
        busy = busy + (load > 0) * dt
        lf_sw = jnp.take(jnp.concatenate([load, swap.astype(f8)]),
                         dual_idx).reshape(2, K, F)
        load_f, swap_f = lf_sw[0], lf_sw[1] > 0.5
        rate = mips_f / jnp.maximum(load_f, 1.0)
        rate = jnp.where(swap_f, rate * swap_slowdown, rate)
        instr = instr - jnp.where(runnable, rate * dt, 0.0)
        newly = runnable & (instr <= 0.0)
        done = done | newly
        hand = newly & hand_static
        hand_r = jnp.concatenate(
            [jnp.zeros((K, 1), bool), hand[:, :-1]], axis=1)
        transfer = jnp.where(hand_r, out_r, transfer)
        newfin = jnp.all(done, axis=1) & ~task_done
        task_done = task_done | newfin
        resp_t = now_s - arrival
        resp_rec = jnp.where(newfin, resp_t, resp_rec)
        finf = newfin.astype(f8)
        mcols = jnp.stack(
            [ones_k, resp_t, (resp_t > sla).astype(f8), acc_t,
             ((resp_t <= sla) + acc_t) / 2.0, wait_s,
             doh[:, 0], doh[:, 1], doh[:, 2]], axis=1)
        m = m + finf @ mcols
        s = stage
        cond = chactive & (s > 0) & (cur_tl > 0.0)
        transfer = transfer - jnp.where(
            cond, bw_s * 1e6 * dt, 0.0)[:, None] * is_stage
        done_s = jnp.take_along_axis(done, s[:, None], axis=1)[:, 0]
        adv = chactive & done_s & (s < nfrag - 1)
        stage = stage + adv.astype(jnp.int32)
        now_s = now_s + dt
        return (instr, done, transfer, stage, task_done, now_s, busy, m,
                resp_rec), None

    done0 = done
    carry = (instr, done, transfer, stage, task_done, now[0],
             jnp.zeros((n,)), metrics, resp)
    (instr, done, transfer, stage, task_done, now_s, busy, metrics,
     resp), _ = jax.lax.scan(body, carry, None, length=substeps)
    completed = done & ~done0
    pwt_delta = jnp.sum(census(completed), axis=0).astype(jnp.float64)
    return (instr, done, transfer, stage, task_done, resp, now_s[None],
            metrics, busy, pwt_delta)


def moe_route_ref(logits, top_k):
    """softmax -> top-k -> first-come slot assignment (token order)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, eids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    S, E = logits.shape
    flat = jax.nn.one_hot(eids.reshape(-1), E, dtype=jnp.int32)
    pos = (jnp.cumsum(flat, axis=0) - 1) * flat
    slots = pos.sum(-1).reshape(S, top_k)
    return eids.astype(jnp.int32), gates, slots.astype(jnp.int32)
