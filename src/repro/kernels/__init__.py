"""Pallas TPU kernels for the compute hot-spots (validated interpret=True
against the pure-jnp oracles in ref.py)."""
from repro.kernels.ops import (  # noqa: F401
    flash_attention, selective_scan, rglru_scan, moe_route,
)
