"""Render a dumped ``RunLedger`` JSONL into a text report.

The report has four fixed sections — provenance, the nested span tree
(wall-clock), runner-cache stats, counters/warnings — plus, when the
ledger carries interval series (``RunLedger.add_series`` of a
``telemetry="interval"`` payload), sparkline curves per column and a
response/wait percentile table computed from the series the same way
``repro.env.metrics.series_percentiles`` does (interval means weighted
by finisher counts; the binning error bound is the largest
within-interval spread).

Stdlib + numpy only, so it runs anywhere the CI artifact lands:

    python tools/obs_report.py benchmarks/results/obs/jaxsim_learned.jsonl
"""
from __future__ import annotations

import argparse
import json

import numpy as np

SPARK = "▁▂▃▄▅▆▇█"


def sparkline(values, width: int = 48) -> str:
    """Down-sample to ``width`` bucket means and map onto eight-level
    block glyphs; constant series render as a flat low line."""
    v = np.asarray(values, np.float64)
    v = v[np.isfinite(v)]
    if v.size == 0:
        return ""
    if v.size > width:
        edges = np.linspace(0, v.size, width + 1).astype(int)
        v = np.array([v[a:b].mean() for a, b in zip(edges[:-1], edges[1:])])
    lo, hi = float(v.min()), float(v.max())
    if hi <= lo:
        return SPARK[0] * v.size
    idx = ((v - lo) / (hi - lo) * (len(SPARK) - 1)).round().astype(int)
    return "".join(SPARK[i] for i in idx)


def _attrs_str(ev) -> str:
    attrs = ev.get("attrs") or {}
    return " ".join(f"{k}={v}" for k, v in sorted(attrs.items()))


def _span_tree(spans, out):
    """Render spans as an indented tree (children under their parent,
    in id order — the order they were opened)."""
    kids = {}
    for ev in spans:
        kids.setdefault(ev.get("parent"), []).append(ev)

    def walk(pid, depth):
        for ev in sorted(kids.get(pid, []), key=lambda e: e["id"]):
            out.append(f"  {'  ' * depth}{ev['name']:<12s}"
                       f"{ev['dur_s']*1e3:10.1f} ms  {_attrs_str(ev)}")
            walk(ev["id"], depth + 1)

    walk(None, 0)


def _series_percentiles(cols, data, qs=(50, 95, 99)):
    """Weighted percentile estimates from a telemetry series (same
    binning as ``repro.env.metrics.series_percentiles``)."""
    idx = {c: i for i, c in enumerate(cols)}
    need = ("n_fin", "sum_resp", "sum_wait", "resp_min", "resp_max",
            "wait_min", "wait_max")
    if any(c not in idx for c in need):
        return None
    nfin = np.rint(data[:, idx["n_fin"]]).astype(np.int64)
    have = nfin > 0
    rows, err = [], 0.0
    for name, s_col, mn, mx in (("response", "sum_resp", "resp_min",
                                 "resp_max"),
                                ("wait", "sum_wait", "wait_min",
                                 "wait_max")):
        if have.any():
            means = data[have, idx[s_col]] / nfin[have]
            vals = np.percentile(np.repeat(means, nfin[have]), qs)
            err = max(err, float(np.max(data[have, idx[mx]]
                                        - data[have, idx[mn]])))
        else:
            vals = np.zeros(len(qs))
        rows.append((name, vals))
    return qs, rows, err


def render(lines) -> str:
    """Format parsed ledger lines (``load_ledger_lines`` output or raw
    ``json.loads`` per line) into the text report."""
    meta = next((ln for ln in lines if ln.get("kind") == "meta"), {})
    spans = [ln for ln in lines if ln.get("kind") == "span"]
    warns = [ln for ln in lines if ln.get("kind") == "warning"]
    counters = next((ln.get("counters", {}) for ln in lines
                     if ln.get("kind") == "counters"), {})
    cache = next((ln for ln in lines if ln.get("kind") == "cache_stats"),
                 None)
    series = [ln for ln in lines if ln.get("kind") == "series"]

    out = [f"== Run ledger: {meta.get('name', '?')} =="]
    prov = meta.get("provenance")
    if prov:
        out.append("  " + " ".join(f"{k}={v}" for k, v in sorted(
            prov.items())))

    out.append("")
    out.append(f"== Span tree == ({len(spans)} spans)")
    if spans:
        total = sum(e["dur_s"] for e in spans if e.get("parent") is None)
        out.append(f"  root wall-clock: {total*1e3:.1f} ms")
        _span_tree(spans, out)
    else:
        out.append("  (none)")

    out.append("")
    out.append("== Runner cache ==")
    if cache is not None:
        out.append(f"  hits={cache.get('hits')} misses={cache.get('misses')}"
                   f" evictions={cache.get('evictions')}"
                   f" size={cache.get('size')}")
        for key, n in sorted((cache.get("keys") or {}).items()):
            out.append(f"  compiled x{n}: {key[:100]}")
    else:
        out.append("  (no snapshot — call ledger.add_cache_stats"
                   "(driver.cache_stats()))")

    out.append("")
    out.append("== Counters ==")
    for k, v in sorted(counters.items()):
        out.append(f"  {k:<28s}{v:>8d}")
    if not counters:
        out.append("  (none)")

    if warns:
        out.append("")
        out.append(f"== Warnings == ({len(warns)})")
        for w in warns:
            out.append(f"  ! {w['message']}")

    for s in series:
        data = np.asarray(s["data"], np.float64)
        out.append("")
        out.append(f"== Series: {s['name']} == "
                   f"({data.shape[0]} intervals x {data.shape[1]} cols)")
        for i, col in enumerate(s["cols"]):
            v = data[:, i]
            out.append(f"  {col:<16s}{sparkline(v)}  "
                       f"min={v.min():.4g} max={v.max():.4g}")
        pct = _series_percentiles(s["cols"], data)
        if pct is not None:
            qs, rows, err = pct
            out.append(f"  percentiles (binned, err<={err:.4g} s):")
            out.append("    " + " " * 9
                       + " ".join(f"{'p%d' % q:>8s}" for q in qs))
            for name, vals in rows:
                out.append(f"    {name:<9s}"
                           + " ".join(f"{v:8.2f}" for v in vals))
    return "\n".join(out) + "\n"


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("ledger", nargs="+",
                    help="dumped RunLedger JSONL path(s)")
    args = ap.parse_args()
    for path in args.ledger:
        with open(path) as f:
            lines = [json.loads(ln) for ln in f if ln.strip()]
        print(f"--- {path} ---")
        print(render(lines))


if __name__ == "__main__":
    main()
