"""Regenerate the golden-trace regression fixtures under tests/data/.

The fixtures pin the jitted backend's per-trace summary metrics (and,
for the train-mode fixture, a finetuned-DASO-theta fingerprint) for two
small fully-deterministic configurations, so backend drift is caught
even when JAX/XLA versions move and the EdgeSim replay oracle would
drift along with the kernels (``tests/test_golden.py`` compares at
``RTOL``).  Everything is derived from literal seeds — no host
pretraining pass — so the fixtures are regeneratable bit-for-bit:

    PYTHONPATH=src python tools/regen_golden.py

Run that (and commit the diff) only when a change *intentionally* moves
the numbers; the test failure message says so too.
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

DATA_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tests", "data")

#: comparison tolerance for the loader test — summary metrics are
#: observed stable to ~1e-15 across reduction orders, so 1e-6 relative
#: flags genuine numeric drift while tolerating XLA refusion noise
RTOL, ATOL = 1e-6, 1e-12


def _mab_state():
    import jax.numpy as jnp

    from repro.core import mab
    return mab.init_state(3)._replace(
        R=jnp.array([700.0, 1800.0, 3500.0], jnp.float32),
        Q=jnp.array([[0.8, 0.6], [0.3, 0.7]], jnp.float32),
        N=jnp.array([[20.0, 10.0], [5.0, 25.0]], jnp.float32),
        eps=jnp.asarray(0.4, jnp.float32),
        rho=jnp.asarray(0.06, jnp.float32),
        t=jnp.asarray(40, jnp.int32))


def _daso(n_workers):
    import jax

    from repro.core import daso
    cfg = daso.DASOConfig(num_workers=n_workers, max_containers=16,
                          state_features=4, hidden=32, depth=2,
                          place_iters=12)
    return daso.init_surrogate(jax.random.PRNGKey(0), cfg), cfg


def theta_fingerprint(theta):
    """Per-layer (L2 norm, abs-sum) pairs — a drift-sensitive digest of
    the finetuned surrogate that stays JSON-small."""
    out = []
    for layer in theta:
        for k in ("w", "b"):
            a = np.asarray(layer[k], np.float64)
            out.append([float(np.sqrt(np.sum(a * a))),
                        float(np.sum(np.abs(a)))])
    return out


def compute_static():
    """Golden case 1: static mixed-decision BestFit trace."""
    from repro.env import jaxsim
    dec = jaxsim.make_static_decider("bestfit-rr")
    tr = jaxsim.compile_trace(dec, lam=5.0, seed=0, n_intervals=8,
                              substeps=4)
    out = jaxsim.run_trace_arrays(tr)
    return {"case": "static bestfit-rr lam=5 seed=0 T=8 substeps=4",
            "summary": {k: float(v) for k, v in out.items()}}


def compute_train():
    """Golden case 2: full in-kernel training loop (ε-greedy MAB +
    DASO finetuning) on a dual trace, incl. the theta fingerprint."""
    from repro.env import jaxsim
    st = _mab_state()
    tr = jaxsim.compile_trace_dual(lam=5.0, seed=3, n_intervals=12,
                                   substeps=4)
    theta, cfg = _daso(50)
    out = jaxsim.run_trace_arrays_trained(tr, st, daso_theta=theta,
                                          daso_cfg=cfg)
    theta_fin = out.pop("daso_theta")
    return {"case": "train splitplace lam=5 seed=3 T=12 substeps=4",
            "summary": {k: float(v) for k, v in out.items()},
            "theta_fingerprint": theta_fingerprint(theta_fin)}


def compute_gillis():
    """Golden case 3: in-kernel Gillis baseline (contextual ε-greedy
    Q-learning, layer vs compressed) on a (LAYER, COMPRESSED) dual
    trace, incl. the full final Q-table."""
    from repro.env import jaxsim
    from repro.env.workload import COMPRESSED, LAYER
    tr = jaxsim.compile_trace_dual(lam=5.0, seed=2, n_intervals=12,
                                   substeps=4,
                                   variants=(LAYER, COMPRESSED))
    out = jaxsim.run_trace_arrays_gillis(tr)
    q = out.pop("gillis_q")
    return {"case": "gillis lam=5 seed=2 T=12 substeps=4",
            "summary": {k: float(v) for k, v in out.items()},
            "gillis_q": np.asarray(q, np.float64).tolist()}


def compute_gobi():
    """Golden case 4: in-kernel MAB + decision-blind GOBI ablation —
    the splitplace surrogate machinery with the decision one-hot masked
    out of the surrogate input."""
    from repro.env import jaxsim
    st = _mab_state()
    theta, cfg = _daso(50)
    tr = jaxsim.compile_trace_dual(lam=5.0, seed=4, n_intervals=10,
                                   substeps=4)
    out = jaxsim.run_trace_arrays_learned(
        tr, st, daso_theta=theta,
        daso_cfg=cfg._replace(decision_aware=False))
    return {"case": "deploy mab+gobi lam=5 seed=4 T=10 substeps=4",
            "summary": {k: float(v) for k, v in out.items()}}


CASES = {
    "golden_static_bestfit_rr.json": compute_static,
    "golden_train_splitplace.json": compute_train,
    "golden_gillis.json": compute_gillis,
    "golden_mab_gobi.json": compute_gobi,
}


def main(argv=None):
    """Regenerate all fixtures, or only the ones named on the command
    line (``python tools/regen_golden.py golden_gillis.json``) — adding
    a new case must not rewrite (and so silently re-bless) the others."""
    args = list(argv if argv is not None else sys.argv[1:])
    names = args or list(CASES)
    unknown = [n for n in names if n not in CASES]
    if unknown:
        raise SystemExit(f"unknown fixture(s) {unknown}; have {list(CASES)}")
    os.makedirs(DATA_DIR, exist_ok=True)
    for fname in names:
        path = os.path.join(DATA_DIR, fname)
        with open(path, "w") as f:
            json.dump(CASES[fname](), f, indent=1, sort_keys=True)
        print(f"wrote {path}")


if __name__ == "__main__":
    sys.exit(main())
