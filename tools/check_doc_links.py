#!/usr/bin/env python
"""Docs link check: fail if README.md / docs/*.md reference repo files
that don't exist.

Two kinds of references are validated:

  * markdown links ``[text](path)`` whose target is a relative path
    (no URL scheme, no in-page anchor-only target);
  * inline code spans that *look like* repo paths — start with a known
    top-level directory (``src/``, ``docs/``, ``benchmarks/``,
    ``examples/``, ``tests/``, ``tools/``) or end in a known source
    suffix — optionally with ``:line`` / ``::member`` tails.

Dotted module paths (``repro.env.jaxsim.arrays``) are resolved against
``src/``.  Run from anywhere: paths resolve against the repo root.

    python tools/check_doc_links.py
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOP_DIRS = ("src/", "docs/", "benchmarks/", "examples/", "tests/",
            "tools/", ".github/")
SUFFIXES = (".py", ".md", ".toml", ".yml", ".yaml", ".json", ".txt")

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")
CODE_SPAN = re.compile(r"``([^`\n]+)``|`([^`\n]+)`")


def _exists(rel: str) -> bool:
    return os.path.exists(os.path.join(ROOT, rel))


def _module_exists(dotted: str) -> bool:
    base = os.path.join(ROOT, "src", *dotted.split("."))
    return os.path.exists(base + ".py") or os.path.isdir(base)


def check_file(path: str):
    errors = []
    text = open(path, encoding="utf-8").read()
    rel_doc = os.path.relpath(path, ROOT)
    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if "://" in target or target.startswith("mailto:"):
            continue
        # links resolve relative to the doc, like a markdown viewer does
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(rel_doc), target))
        if not _exists(resolved):
            errors.append(f"{rel_doc}: dangling link -> {target}")
    for m in CODE_SPAN.finditer(text):
        span = (m.group(1) or m.group(2)).strip()
        # strip :line / ::member / call-paren tails
        span = re.split(r"::|[:(]", span, 1)[0].strip()
        if not span or " " in span or "*" in span or "{" in span:
            continue
        if span.startswith(TOP_DIRS) or \
                (("/" in span) and span.endswith(SUFFIXES)):
            if not _exists(span):
                errors.append(f"{rel_doc}: dangling path `{span}`")
        elif re.fullmatch(r"repro(\.\w+)+", span):
            if not _module_exists(span):
                errors.append(f"{rel_doc}: dangling module `{span}`")
    return errors


def main() -> int:
    docs = [os.path.join(ROOT, "README.md")]
    docs += sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    all_errors = []
    for doc in docs:
        if os.path.exists(doc):
            all_errors += check_file(doc)
    for e in all_errors:
        print(f"ERROR: {e}")
    print(f"checked {len(docs)} docs: "
          f"{'FAIL' if all_errors else 'ok'} ({len(all_errors)} dangling)")
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main())
