"""Merge ``benchmarks/results/*.json`` into one trajectory artifact.

Each benchmark writes its own provenance-stamped JSON (see
``benchmarks/_provenance.py``); this tool folds every artifact in a
results directory into a single ``summary.json`` so one file captures
the whole benchmark trajectory of a run — what was measured, on which
jax/device fleet, with which dispatch knobs (``substep_impl`` /
``devices``), and the headline scalar per benchmark.  Run-ledger
dumps under ``<dir>/obs/*.jsonl`` (``benchmarks/_provenance.obs_scope``)
contribute their runner-cache snapshots and warning counts to an
``obs`` block, so the summary also records how the compiled-executable
cache behaved during the trajectory.

``python tools/bench_summary.py [--dir benchmarks/results]
[--out benchmarks/results/summary.json]``
"""
from __future__ import annotations

import argparse
import glob
import json
import os

#: per-artifact headline scalar: (key path into the artifact) — first
#: path that resolves wins; purely informational, absent paths skip
_HEADLINES = {
    "jaxsim_grid": ("speedup_8_traces",
                    ("devices_scaling", "speedup_vs_single_device")),
    "jaxsim_grid_devices": (("devices_scaling",
                             "speedup_vs_single_device"),),
    "jaxsim_learned": ("speedup_8_traces",),
    "jaxsim_learned_train": ("speedup_8_traces",),
    "jaxsim_baselines": (("arms", "gillis", "speedup_8_traces"),),
    "sim_throughput": ("speedup", ("soa", "speedup")),
    "stream_serve": (("soak", "steady_tasks_per_sec"),),
}


def _resolve(obj, path):
    if isinstance(path, str):
        path = (path,)
    for k in path:
        if not isinstance(obj, dict) or k not in obj:
            return None
        obj = obj[k]
    return obj if isinstance(obj, (int, float)) else None


def _obs_block(results_dir: str) -> dict:
    """Fold each ledger JSONL under ``<dir>/obs/`` into its cache-stats
    snapshot + span/warning counts (the full ledger stays in the
    artifact upload; the summary keeps the scalars)."""
    obs = {}
    for path in sorted(glob.glob(os.path.join(results_dir, "obs",
                                              "*.jsonl"))):
        name = os.path.splitext(os.path.basename(path))[0]
        try:
            with open(path) as f:
                lines = [json.loads(ln) for ln in f if ln.strip()]
        except (OSError, json.JSONDecodeError) as e:
            obs[name] = {"error": f"{type(e).__name__}: {e}"}
            continue
        cache = next((ln for ln in lines
                      if ln.get("kind") == "cache_stats"), {})
        obs[name] = {
            "cache_stats": {k: v for k, v in cache.items()
                            if k not in ("kind", "keys")},
            "n_spans": sum(ln.get("kind") == "span" for ln in lines),
            "n_warnings": sum(ln.get("kind") == "warning"
                              for ln in lines),
        }
    return obs


def merge(results_dir: str = "benchmarks/results",
          out_json: str | None = None) -> dict:
    arts = {}
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        name = os.path.splitext(os.path.basename(path))[0]
        if name == "summary":
            continue
        try:
            with open(path) as f:
                arts[name] = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            arts[name] = {"error": f"{type(e).__name__}: {e}"}
    merged = {"n_artifacts": len(arts), "benchmarks": arts,
              "provenance": {n: a.get("provenance")
                             for n, a in arts.items()
                             if isinstance(a, dict)},
              "obs": _obs_block(results_dir),
              "headlines": {}}
    for name, art in arts.items():
        for path in _HEADLINES.get(name, ()):
            v = _resolve(art, path)
            if v is not None:
                merged["headlines"][name] = v
                break
    if out_json:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(merged, f, indent=1)
    return merged


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results")
    ap.add_argument("--out", default="benchmarks/results/summary.json")
    args = ap.parse_args()
    merged = merge(args.dir, out_json=args.out)
    print(f"merged {merged['n_artifacts']} artifacts -> {args.out}")
    for name, v in sorted(merged["headlines"].items()):
        print(f"  {name:24s} {v:8.2f}")
    for name, o in sorted(merged["obs"].items()):
        cs = o.get("cache_stats") or {}
        print(f"  obs/{name}: cache hits={cs.get('hits')} "
              f"misses={cs.get('misses')} warnings={o.get('n_warnings')}")


if __name__ == "__main__":
    main()
